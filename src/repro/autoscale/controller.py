"""AutoscaleController — the sense/act halves of the autoscale loop.

The paper's deployment is statically provisioned: one agent per cluster,
sized by hand (§4 runs three fixed pools for the AlphaKnot campaign). That
leaves the utilization gap ParaFold (arXiv:2111.06340) and APACE
(arXiv:2308.07954) both attack — CPU-stage backlog piles up while the GPU
pool idles, and vice versa. With per-resource-class topics the gap is
mechanically fixable: **queue depth per class is the demand signal**, and
the :class:`~repro.cluster.KsaCluster` facade is the actuator.

Control loop, once per ``interval_s`` and per pool:

1. **sense** — :meth:`Broker.queue_stats` gives the class topic's depth and
   cumulative consumed count under the shared agents group (incremental
   counters, no record scans); pool agents' ``in_flight``/``deferred``
   stats complete the demand picture, and successive consumed samples give
   the drain rate;
2. **decide** — the pluggable :class:`~repro.autoscale.policy.ScalingPolicy`
   (default :class:`~repro.autoscale.policy.TargetBacklogPolicy`) maps the
   signal to a desired agent count, with hysteresis/cooldown/min/max inside
   the policy and a final clamp here;
3. **act** — grow through ``KsaCluster.add_worker`` / ``add_slurm`` (the
   same calls a human operator uses), shrink through the agents' graceful
   drain (:meth:`~repro.core.agents.AgentBase.request_drain`): the draining
   agent leaves the consumer group, requeues its deferred leases, finishes
   its in-flight tasks, and is deregistered from the facade once stopped —
   no task lost, none double-run (asserted by knot-count parity in
   tests/test_autoscale.py).

Every decision is recorded (served on the monitor's ``/autoscale`` REST
endpoint together with per-pool backlog history), so scaling behaviour is
observable the same way task status is (§3's web-based REST API).
"""
from __future__ import annotations

import logging
import threading
import time
from collections import deque
from typing import TYPE_CHECKING, Any

from repro.core.agents import AgentBase
from repro.core.scheduling import class_topic
from repro.obs import TimeSeriesStore

from .policy import AutoscaleConfig, AutoscaleError, PoolSignal, PoolSpec

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.cluster import KsaCluster

log = logging.getLogger(__name__)

_LONG_AGO = -1e12  # "never": makes every since_* duration effectively inf


class _PoolState:
    """Mutable runtime state of one elastic pool (controller-private)."""

    def __init__(self, spec: PoolSpec, history: int, rate_window_s: float):
        self.spec = spec
        self.agents: list[AgentBase] = []    # serving members
        self.draining: list[AgentBase] = []  # leaving members (finish work)
        self.last_scale_up = _LONG_AGO
        self.last_scale_down = _LONG_AGO
        self.idle_since: float | None = None
        # backlog/agents/in_flight/consumed samples live in the
        # controller's TimeSeriesStore (``src="autoscale"`` series), not
        # in per-pool rings — history and drain rate are store queries
        self.history_len = history
        self.rate_window_s = rate_window_s
        self.scale_ups = 0
        self.scale_downs = 0
        # when the class backlog last went 0 -> nonzero; the age of this
        # mark at the moment a grow is decided is the decision lag
        self.pressure_since: float | None = None


class AutoscaleController:
    """Backlog-driven elastic scaling of a :class:`KsaCluster`'s pools.

    Normally built by the facade (``KsaCluster(autoscale=cfg)``); the
    controller spawns each pool's ``min_agents`` on :meth:`start` and then
    adjusts within ``[min_agents, max_agents]`` as the per-class backlog
    moves. Direct construction against a started cluster is supported for
    tests and embedders.
    """

    def __init__(self, cluster: "KsaCluster", config: AutoscaleConfig,
                 store: TimeSeriesStore | None = None):
        self.cluster = cluster
        self.config = config
        # sensing is store-backed (ISSUE 9): samples land in the cluster's
        # telemetry TimeSeriesStore when the plane is on, or a private one
        # otherwise — either way a lookahead policy reads history from the
        # same query surface operators do, and swapping it in is a pure
        # policy change. The ``src="autoscale"`` label keeps these series
        # disjoint from registry-snapshot series folded by the collector.
        if store is None:
            store = getattr(cluster, "telemetry_store", None)
        if store is None:
            store = TimeSeriesStore(
                resolution_s=max(0.01, min(0.25, config.interval_s / 2)),
                max_buckets=max(64, 4 * config.history))
        self.store = store
        classes = getattr(cluster.placement, "classes", None)
        if classes is not None:
            known = set(classes())
            for p in config.pools:
                if p.cls not in known:
                    raise AutoscaleError(
                        f"pool class {p.cls!r} is not a resource class of "
                        f"the cluster's placement policy (known: "
                        f"{sorted(known)}); declare it via "
                        f"ResourceClassPolicy(extra_classes=...)")
        self._pools = {p.cls: _PoolState(p, config.history,
                                         config.rate_window_s)
                       for p in config.pools}
        self._decisions: deque[dict] = deque(maxlen=128)
        self._group = f"{cluster.prefix}-agents"
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.ticks = 0
        metrics = cluster.broker.metrics
        self._c_scaled = metrics.counter(
            "ksa_autoscale_decisions_total",
            "Scaling decisions recorded, by pool and direction",
            labels=("pool", "action"))
        self._h_tick = metrics.histogram(
            "ksa_autoscale_tick_seconds",
            "Sense/decide/act duration of one control-loop pass")
        self._h_lag = metrics.histogram(
            "ksa_autoscale_decision_lag_seconds",
            "Backlog appearing -> scale-up decision lag, per pool",
            labels=("pool",))
        self._g_agents = metrics.gauge(
            "ksa_pool_agents", "Serving agents per elastic pool",
            labels=("pool",))
        self._g_backlog = metrics.gauge(
            "ksa_pool_backlog", "Class-topic backlog per elastic pool",
            labels=("pool",))

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "AutoscaleController":
        with self._lock:
            for pool in self._pools.values():  # provision floors up front
                if pool.spec.min_agents > len(pool.agents):
                    self._grow(pool, pool.spec.min_agents - len(pool.agents),
                               reason="min_agents floor")
        self._thread = threading.Thread(target=self._loop,
                                        name="autoscale-loop", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        """Stop the control loop. Pool agents stay registered on the
        cluster — the facade's own teardown stops them."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.tick()
            except Exception:  # pragma: no cover - defensive
                log.exception("autoscale tick failed")
            self._stop.wait(self.config.interval_s)

    # -- sense / decide / act ------------------------------------------------

    def tick(self) -> None:
        """One control-loop pass over every pool (public for deterministic
        tests: drive ticks by hand with the loop thread never started)."""
        now = time.time()
        t_tick = time.perf_counter()
        topics = {cls: class_topic(self.cluster.prefix, cls)
                  for cls in self._pools}
        qs = self.cluster.broker.queue_stats(self._group,
                                             list(topics.values()))
        with self._lock:
            self.ticks += 1
            for cls, pool in self._pools.items():
                self._reap(pool)
                stats = qs[topics[cls]]
                backlog = stats["depth"]
                if backlog <= 0:
                    pool.pressure_since = None
                elif pool.pressure_since is None:
                    pool.pressure_since = now
                in_flight = 0
                for a in pool.agents:
                    s = a.stats()
                    in_flight += s["in_flight"] + s["deferred_pending"]
                lbl = {"pool": cls, "src": "autoscale"}
                self.store.ingest_many([
                    ("ksa_pool_consumed_total", lbl, now,
                     stats["consumed"], "counter"),
                    ("ksa_pool_backlog", lbl, now, backlog, "gauge"),
                    ("ksa_pool_agents", lbl, now, len(pool.agents),
                     "gauge"),
                    ("ksa_pool_in_flight", lbl, now, in_flight, "gauge"),
                ])
                if backlog > 0 or in_flight > 0:
                    pool.idle_since = None
                elif pool.idle_since is None:
                    pool.idle_since = now
                sig = PoolSignal(
                    cls=cls, backlog=backlog, in_flight=in_flight,
                    agents=len(pool.agents), slots=pool.spec.slots,
                    drain_rate=self.store.rate(
                        "ksa_pool_consumed_total", lbl,
                        pool.rate_window_s, now),
                    idle_for_s=(0.0 if pool.idle_since is None
                                else now - pool.idle_since),
                    since_scale_up_s=now - pool.last_scale_up,
                    since_scale_down_s=now - pool.last_scale_down)
                desired = self.config.policy.desired(sig, pool.spec)
                desired = max(pool.spec.min_agents,
                              min(pool.spec.max_agents, desired))
                if desired > sig.agents:
                    if pool.pressure_since is not None:
                        # the lag this pool's backlog waited for capacity;
                        # the episode is answered, so re-arm the mark
                        self._h_lag.labels(pool=cls).observe(
                            now - pool.pressure_since)
                        pool.pressure_since = None
                    self._grow(pool, desired - sig.agents,
                               reason=f"backlog {backlog} "
                                      f"({sig.backlog_per_slot:.1f}/slot)")
                elif desired < sig.agents:
                    self._shrink(pool, sig.agents - desired,
                                 reason=f"idle {sig.idle_for_s:.2f}s")
                self._g_agents.labels(pool=cls).set(len(pool.agents))
                self._g_backlog.labels(pool=cls).set(backlog)
        self._h_tick.observe(time.perf_counter() - t_tick)

    def _reap(self, pool: _PoolState) -> None:
        """Deregister drained (or crashed) members from the facade."""
        for a in list(pool.draining):
            if not a.alive:
                pool.draining.remove(a)
                self.cluster._forget_agent(a)
                log.info("pool %s: %s drained and deregistered",
                         pool.spec.cls, a.agent_id)
        for a in list(pool.agents):
            if not a.alive:  # crashed / externally stopped
                pool.agents.remove(a)
                self.cluster._forget_agent(a)

    def _grow(self, pool: _PoolState, n: int, *, reason: str) -> None:
        spec = pool.spec
        for _ in range(n):
            kw = dict(spec.agent_kw or {})
            if spec.kind == "slurm":
                agent = self.cluster.add_slurm(dict(spec.slurm or {}), **kw)
            else:
                agent = self.cluster.add_worker(
                    slots=spec.slots, profile=spec.resolve_profile(), **kw)
            pool.agents.append(agent)
        pool.last_scale_up = time.time()
        pool.scale_ups += n
        self._record(pool, "up", n, reason)

    def _shrink(self, pool: _PoolState, n: int, *, reason: str) -> None:
        # drain the least-loaded members first: their in-flight work (and
        # therefore the drain) finishes soonest
        victims = sorted(pool.agents,
                         key=lambda a: a.stats()["in_flight"])[:n]
        for a in victims:
            pool.agents.remove(a)
            a.request_drain(timeout_s=self.config.drain_timeout_s)
            pool.draining.append(a)
        pool.last_scale_down = time.time()
        pool.scale_downs += len(victims)
        self._record(pool, "down", len(victims), reason)

    def _record(self, pool: _PoolState, action: str, n: int,
                reason: str) -> None:
        d = {"ts": time.time(), "pool": pool.spec.cls, "action": action,
             "count": n, "agents": len(pool.agents),
             "draining": len(pool.draining), "reason": reason}
        self._decisions.append(d)
        self._c_scaled.labels(pool=pool.spec.cls, action=action).inc()
        log.info("autoscale %s: %s x%d -> %d agents (%s)", pool.spec.cls,
                 action, n, len(pool.agents), reason)

    # -- observability -------------------------------------------------------

    def pool_size(self, cls: str) -> int:
        with self._lock:
            return len(self._pools[cls].agents)

    @property
    def scale_ups(self) -> int:
        with self._lock:
            return sum(p.scale_ups for p in self._pools.values())

    @property
    def scale_downs(self) -> int:
        with self._lock:
            return sum(p.scale_downs for p in self._pools.values())

    def pool_history(self, cls: str, *,
                     limit: int | None = None) -> list[list]:
        """Store-backed ``[[ts, backlog, agents, in_flight], ...]`` rows
        for one pool, joined across the ``src="autoscale"`` series on the
        shared tick timestamp (downsampled to the store's bucket
        resolution)."""
        lbl = {"pool": cls, "src": "autoscale"}
        backlog = self.store.points("ksa_pool_backlog", lbl)
        agents = dict(self.store.points("ksa_pool_agents", lbl))
        in_flight = dict(self.store.points("ksa_pool_in_flight", lbl))
        rows = [[round(ts, 3), int(b), int(agents.get(ts, 0)),
                 int(in_flight.get(ts, 0))] for ts, b in backlog]
        if limit is not None:
            rows = rows[-limit:]
        return rows

    def status(self, *, history: int = 64) -> dict:
        """The ``/autoscale`` payload: per-pool membership, live signal
        components, recent backlog history, and the decision log."""
        now = time.time()
        with self._lock:
            pools: dict[str, Any] = {}
            for cls, pool in self._pools.items():
                lbl = {"pool": cls, "src": "autoscale"}
                hist = self.pool_history(
                    cls, limit=min(history, pool.history_len))
                pools[cls] = {
                    "kind": pool.spec.kind,
                    "min": pool.spec.min_agents,
                    "max": pool.spec.max_agents,
                    "slots": pool.spec.slots,
                    "agents": len(pool.agents),
                    "draining": len(pool.draining),
                    "agent_ids": [a.agent_id for a in pool.agents],
                    "backlog": hist[-1][1] if hist else 0,
                    "in_flight": hist[-1][3] if hist else 0,
                    "drain_rate": self.store.rate(
                        "ksa_pool_consumed_total", lbl,
                        pool.rate_window_s, now),
                    "scale_ups": pool.scale_ups,
                    "scale_downs": pool.scale_downs,
                    "history": hist,
                }
            return {
                "ticks": self.ticks,
                "interval_s": self.config.interval_s,
                "policy": type(self.config.policy).__name__,
                "pools": pools,
                "decisions": list(self._decisions),
                # unified stop-path telemetry: every scale-down drain's
                # requeues show up as reason="drain" revocations here,
                # alongside watchdog/preempt/mem_overage/scancel ones — one
                # ledger for every way the control plane takes work back
                "leases": self.cluster.broker.lease_stats(),
            }
