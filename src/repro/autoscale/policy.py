"""Scaling policies — the *decide* half of the autoscale control loop.

A policy maps one pool's sensed :class:`PoolSignal` to a desired agent
count. Policies are **stateless by contract**: every clock the decision
depends on (idle duration, time since the last scale action) arrives inside
the signal, so a policy is a pure function and its hysteresis/cooldown
behaviour is unit-testable without threads, brokers, or sleeps
(tests/test_autoscale.py drives synthetic signal sequences through it).

The default :class:`TargetBacklogPolicy` implements the queue-theoretic
rule APACE (arXiv:2308.07954) uses for elastic AlphaFold serving — size the
pool so the per-slot backlog stays near a target — with the guard rails a
bang-bang controller needs on a real queue:

* **hysteresis** — the scale-up condition (backlog per slot above ``high``)
  and the scale-down condition (pool completely idle for ``idle_grace_s``)
  cannot both hold, and a backlog oscillating anywhere between them changes
  nothing;
* **cooldowns** — consecutive scale actions are separated by
  ``up_cooldown_s`` / ``down_cooldown_s``, so a burst landing faster than
  agents can start (or a SimSlurm node can spin up) does not over-provision,
  and a brief gap between bursts does not tear the pool down;
* **bounded step-down** — the pool shrinks one agent per decision (each
  shrink is a graceful drain; stepping down gently keeps capacity available
  while the drain completes), while scale-up jumps straight to the demand
  estimate (queues punish under-provisioning harder than over-provisioning);
* **scale-to-zero** — a pool whose ``min_agents`` is 0 (typically a tainted
  ``serve`` pool) drops to zero agents when idle and wakes on the first
  queued task regardless of cooldown: the cold start already costs enough.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping

from repro.core.scheduling import ResourceProfile


class AutoscaleError(ValueError):
    """Raised for malformed pool specs / configs."""


# --------------------------------------------------------------------------
# What one elastic pool is (declarative)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """One elastic agent pool serving one resource class.

    ``cls`` names the resource class whose ``PREFIX-new.<cls>`` backlog
    drives the pool ("cpu", "gpu", or a label/taint class the placement
    policy knows). ``kind`` selects the actuator: ``"worker"`` pools grow by
    in-process :class:`~repro.core.agents.WorkerAgent`\\ s with ``slots``
    each; ``"slurm"`` pools grow by attaching a fresh
    :class:`~repro.core.simslurm.SimSlurm` (built from the ``slurm`` kwargs,
    e.g. ``dict(nodes=1, cpus_per_node=4, spinup_s=2.0)``) behind a
    ClusterAgent — the spin-up latency then shows up as backlog that the
    cooldown must ride out rather than double-provision against.

    ``profile`` defaults by class: plain cpu/gpu worker profiles sized to
    ``slots``, and for any other class a tainted, labelled profile — i.e. an
    exclusive pool that only drains tolerated/labelled work, the natural
    scale-to-zero candidate (``min_agents=0``).
    """

    cls: str
    kind: str = "worker"                     # "worker" | "slurm"
    min_agents: int = 0
    max_agents: int = 4
    slots: int = 1
    profile: ResourceProfile | None = None
    slurm: Mapping[str, Any] | None = None   # SimSlurm kwargs (kind="slurm")
    agent_kw: Mapping[str, Any] | None = None

    def __post_init__(self) -> None:
        if self.kind not in ("worker", "slurm"):
            raise AutoscaleError(f"pool {self.cls!r}: unknown kind "
                                 f"{self.kind!r} (worker|slurm)")
        if self.min_agents < 0 or self.max_agents < max(1, self.min_agents):
            raise AutoscaleError(
                f"pool {self.cls!r}: need 0 <= min_agents <= max_agents "
                f"(got {self.min_agents}..{self.max_agents})")
        if self.slots <= 0:
            raise AutoscaleError(f"pool {self.cls!r}: slots must be positive")
        if self.slurm is not None and self.kind != "slurm":
            raise AutoscaleError(
                f"pool {self.cls!r}: slurm kwargs on a worker pool")

    def resolve_profile(self) -> ResourceProfile:
        """The profile each grown agent declares (worker pools)."""
        if self.profile is not None:
            return self.profile
        if self.cls == "cpu":
            return ResourceProfile(cpus=self.slots, mem_mb=1024 * self.slots)
        if self.cls == "gpu":
            return ResourceProfile(cpus=self.slots, gpus=1,
                                   mem_mb=1024 * self.slots)
        # label/taint class: an exclusive pool that serves only its class
        return ResourceProfile(cpus=self.slots, mem_mb=1024 * self.slots,
                               labels=(self.cls,), taints=(self.cls,))


# --------------------------------------------------------------------------
# What the controller senses (per pool, per tick)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PoolSignal:
    """One pool's sensed state at one control-loop tick. All times are
    durations relative to the tick (no wall-clock), keeping policies pure."""

    cls: str
    backlog: int              # queue depth on the class topic (unleased)
    in_flight: int            # running + deferred leases on pool agents
    agents: int               # live, non-draining agents
    slots: int                # slots per agent
    drain_rate: float         # tasks/s the agents group is committing
    idle_for_s: float         # how long backlog == 0 and in_flight == 0
    since_scale_up_s: float   # time since this pool last grew
    since_scale_down_s: float  # time since this pool last shrank

    @property
    def backlog_per_slot(self) -> float:
        return self.backlog / max(1, self.agents * self.slots)


# --------------------------------------------------------------------------
# Policies
# --------------------------------------------------------------------------


class ScalingPolicy:
    """Maps a :class:`PoolSignal` to a desired agent count for one pool.
    The controller clamps the answer to ``[min_agents, max_agents]`` and
    enacts the difference (grow = spawn agents, shrink = graceful drain)."""

    def desired(self, sig: PoolSignal, spec: PoolSpec) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class TargetBacklogPolicy(ScalingPolicy):
    """Target backlog-per-slot with hysteresis and cooldowns (see module
    docstring). ``target`` is the backlog depth per slot the pool is sized
    for when growing (2.0 ≈ the paper's keep-the-queue-full oversubscription
    strategy, applied to pool size instead of the Slurm queue); ``high`` is
    the per-slot backlog that triggers growth."""

    target: float = 2.0
    high: float = 1.0
    idle_grace_s: float = 0.5
    up_cooldown_s: float = 0.25
    down_cooldown_s: float = 0.5

    def __post_init__(self) -> None:
        if self.target <= 0 or self.high <= 0:
            raise AutoscaleError("target and high must be positive")

    def desired(self, sig: PoolSignal, spec: PoolSpec) -> int:
        demand = sig.backlog + sig.in_flight
        if demand <= 0:
            # fully idle: step down one agent at a time, after the idle
            # grace AND the down cooldown (hysteresis band: a backlog that
            # flickers 0 ↔ below-high changes nothing either way)
            if (sig.idle_for_s >= self.idle_grace_s
                    and sig.since_scale_down_s >= self.down_cooldown_s
                    and sig.since_scale_up_s >= self.down_cooldown_s):
                return max(spec.min_agents, sig.agents - 1)
            return max(spec.min_agents, sig.agents)
        if sig.agents == 0:
            # scale-to-zero wake: queued work on an empty pool overrides
            # every cooldown — the cold start is already the price
            return self._sized_for(demand, spec)
        if sig.backlog_per_slot > self.high \
                and sig.since_scale_up_s >= self.up_cooldown_s:
            return max(sig.agents + 1, self._sized_for(demand, spec))
        return sig.agents  # in the hysteresis band: hold

    def _sized_for(self, demand: int, spec: PoolSpec) -> int:
        want = math.ceil(demand / (self.target * spec.slots))
        return max(1, min(spec.max_agents, want))


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Wiring for :class:`~repro.autoscale.controller.AutoscaleController`,
    passed as ``KsaCluster(autoscale=AutoscaleConfig(...))``.

    ``drain_timeout_s`` bounds each scale-down drain: a task still running
    at the deadline is cancelled and redelivered (at-least-once) instead of
    pinning the drained agent forever. ``rate_window_s`` is the lookback for
    the drain-rate estimate served on ``/autoscale``."""

    pools: tuple[PoolSpec, ...] = ()
    policy: ScalingPolicy = dataclasses.field(
        default_factory=TargetBacklogPolicy)
    interval_s: float = 0.05
    drain_timeout_s: float | None = 30.0
    rate_window_s: float = 2.0
    history: int = 512            # backlog samples retained per pool

    def __post_init__(self) -> None:
        object.__setattr__(self, "pools", tuple(self.pools))
        if not self.pools:
            raise AutoscaleError("AutoscaleConfig needs at least one PoolSpec")
        seen = set()
        for p in self.pools:
            if p.cls in seen:
                raise AutoscaleError(f"duplicate pool for class {p.cls!r}")
            seen.add(p.cls)
        if self.interval_s <= 0:
            raise AutoscaleError("interval_s must be positive")
