"""recurrentgemma-2b [arXiv:2402.19427]. Assigned: 26L d2560 10H (kv=1)
d_ff=7680 vocab=256000, RG-LRU + local attention at 1:2 (pattern
(rglru, rglru, local), window 2048), lru_width 2560, head_dim 256."""
from repro.models.config import ModelConfig, RGLRUConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, vocab_size=256000,
        n_heads=10, n_kv_heads=1, head_dim=256, d_ff=7680,
        layer_pattern=("rglru", "rglru", "local"),
        window_size=2048, mlp_kind="geglu",
        rglru=RGLRUConfig(lru_width=2560, conv_width=4),
        tie_embeddings=True, scale_embeddings=True,
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=5, d_model=64, vocab_size=512,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=160,
        layer_pattern=("rglru", "rglru", "local"),
        window_size=32, mlp_kind="geglu",
        rglru=RGLRUConfig(lru_width=64, conv_width=4),
        tie_embeddings=True, scale_embeddings=True,
        dtype="float32", kv_chunk=64,
    )
