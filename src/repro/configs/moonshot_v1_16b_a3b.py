"""moonshot-v1-16b-a3b — Moonlight-style MoE, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B]. Assigned: 48L d2048 16H (kv=16) d_ff=1408
(expert dim) vocab=163840."""
from repro.models.config import MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, vocab_size=163840,
        n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=0,  # all FFN capacity is in the experts
        layer_pattern=("attn",),
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      capacity_factor=1.25),
        rope_theta=50_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke", family="moe",
        n_layers=2, d_model=64, vocab_size=512,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=0,
        layer_pattern=("attn",),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=96, n_shared=1,
                      capacity_factor=8.0),
        dtype="float32", kv_chunk=64,
    )
