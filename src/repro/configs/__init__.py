"""Architecture registry: the 10 assigned configs + shapes + cell rules.

Every entry provides:

* ``config()``        — the exact assigned full-size :class:`ModelConfig`,
* ``smoke_config()``  — a reduced same-family config for CPU smoke tests,
* shape cells via :func:`cells_for` with the assignment's skip rules.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass

from repro.models.config import ModelConfig

ARCHS = (
    "moonshot_v1_16b_a3b",
    "deepseek_v3_671b",
    "stablelm_1_6b",
    "gemma3_1b",
    "internlm2_1_8b",
    "gemma3_4b",
    "hubert_xlarge",
    "recurrentgemma_2b",
    "internvl2_1b",
    "mamba2_130m",
)

# canonical ids as given in the assignment (dashes)
CANONICAL = {a: a.replace("_", "-").replace("-1-6b", "-1.6b")
             .replace("-1-8b", "-1.8b") for a in ARCHS}


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# long_500k runs only for sub-quadratic stacks (SSM / hybrid / mostly-local);
# decode shapes are skipped for encoder-only archs. See DESIGN.md §4.
_SUBQUADRATIC = {"mamba2_130m", "recurrentgemma_2b", "gemma3_1b", "gemma3_4b"}


def _norm(name: str) -> str:
    key = name.replace("-", "_").replace(".", "_")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return key


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.config()


def smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.smoke_config()


def cells_for(name: str) -> list[Shape]:
    key = _norm(name)
    cfg = get_config(key)
    out = []
    for shape in SHAPES.values():
        if shape.step == "decode" and cfg.encoder_only:
            continue  # no decode step for encoders
        if shape.name == "long_500k" and key not in _SUBQUADRATIC:
            continue  # needs sub-quadratic attention
        out.append(shape)
    return out


def all_cells() -> list[tuple[str, Shape]]:
    return [(a, s) for a in ARCHS for s in cells_for(a)]
