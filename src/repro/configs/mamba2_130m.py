"""mamba2-130m [arXiv:2405.21060]. Assigned: 24L d768 (attn-free) d_ff=0
vocab=50280, ssm_state=128, SSD. expand=2 -> d_inner 1536, head_dim 64 ->
24 SSD heads."""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, vocab_size=50280,
        d_ff=0,
        layer_pattern=("ssd",),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk_size=256),
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=3, d_model=64, vocab_size=512,
        d_ff=0,
        layer_pattern=("ssd",),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      chunk_size=32),
        tie_embeddings=True,
        dtype="float32", kv_chunk=64,
    )
