"""gemma3-1b [hf:google/gemma-3-1b-pt]. Assigned: 26L d1152 4H (kv=1)
d_ff=6912 vocab=262144, 5:1 local:global (window 512), 128k context.
Gemma-3 particulars: head_dim 256, qk-norm, tied + scaled embeddings, geglu,
RoPE theta 10k local / 1M global."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, vocab_size=262144,
        n_heads=4, n_kv_heads=1, head_dim=256, d_ff=6912,
        layer_pattern=("local",) * 5 + ("attn",),
        window_size=512, mlp_kind="geglu",
        use_qk_norm=True, tie_embeddings=True, scale_embeddings=True,
        rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b-smoke", family="dense",
        n_layers=8, d_model=64, vocab_size=512,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=160,
        layer_pattern=("local",) * 2 + ("attn",),
        window_size=32, mlp_kind="geglu",
        use_qk_norm=True, tie_embeddings=True, scale_embeddings=True,
        dtype="float32", kv_chunk=64,
    )
