"""deepseek-v3-671b — MLA + 256-expert MoE (1 shared, top-8)
[arXiv:2412.19437]. Assigned: 61L d_model=7168 128H d_ff=2048 (expert dim)
vocab=129280. MLA dims per the paper: q_lora 1536, kv_lora 512, rope 64,
nope 128, v 128. (MTP head is an optional extension, see DESIGN.md.)"""
from repro.models.config import MLAConfig, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, vocab_size=129280,
        n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=0,
        layer_pattern=("attn",),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                      capacity_factor=1.25),
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe",
        n_layers=2, d_model=64, vocab_size=512,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=0,
        layer_pattern=("attn",),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=96, n_shared=1,
                      capacity_factor=8.0),
        dtype="float32", kv_chunk=64,
    )
