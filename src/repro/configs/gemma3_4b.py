"""gemma3-4b [hf:google/gemma-3-4b family]. Assigned: 34L d2560 8H (kv=4)
d_ff=10240 vocab=262144, 5:1 local:global (window 1024)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, vocab_size=262144,
        n_heads=8, n_kv_heads=4, head_dim=256, d_ff=10240,
        layer_pattern=("local",) * 5 + ("attn",),
        window_size=1024, mlp_kind="geglu",
        use_qk_norm=True, tie_embeddings=True, scale_embeddings=True,
        rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-smoke", family="dense",
        n_layers=8, d_model=64, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
        layer_pattern=("local",) * 2 + ("attn",),
        window_size=32, mlp_kind="geglu",
        use_qk_norm=True, tie_embeddings=True, scale_embeddings=True,
        dtype="float32", kv_chunk=64,
    )
