"""internvl2-1b [arXiv:2404.16821]. Assigned: 24L d896 14H (kv=2) d_ff=4864
vocab=151655. InternViT frontend is a STUB: inputs are precomputed 1024-dim
patch embeddings (256 patches) projected and prepended to the text."""
from repro.models.config import FrontendConfig, ModelConfig

N_PATCHES = 256


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, vocab_size=151655,
        n_heads=14, n_kv_heads=2, head_dim=64, d_ff=4864,
        layer_pattern=("attn",),
        frontend=FrontendConfig(kind="vit_patches", input_dim=1024,
                                n_positions=N_PATCHES),
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=64, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
        layer_pattern=("attn",),
        frontend=FrontendConfig(kind="vit_patches", input_dim=32,
                                n_positions=8),
        dtype="float32", kv_chunk=64,
    )
