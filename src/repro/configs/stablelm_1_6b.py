"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b]. Assigned: 24L d2048 32H
(kv=32) d_ff=5632 vocab=100352."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        n_layers=24, d_model=2048, vocab_size=100352,
        n_heads=32, n_kv_heads=32, head_dim=64, d_ff=5632,
        layer_pattern=("attn",),
        rope_theta=10_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, vocab_size=512,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=160,
        layer_pattern=("attn",),
        dtype="float32", kv_chunk=64,
    )
