"""hubert-xlarge [arXiv:2106.07447]. Assigned: 48L d1280 16H (kv=16)
d_ff=5120 vocab=504 (k-means target units), encoder-only. The conv waveform
frontend is a STUB: inputs are precomputed 512-dim frame embeddings."""
from repro.models.config import FrontendConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, vocab_size=504,
        n_heads=16, n_kv_heads=16, head_dim=80, d_ff=5120,
        layer_pattern=("attn",), mlp_kind="gelu",
        encoder_only=True,
        frontend=FrontendConfig(kind="audio_frames", input_dim=512),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="audio",
        n_layers=2, d_model=64, vocab_size=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        layer_pattern=("attn",), mlp_kind="gelu",
        encoder_only=True,
        frontend=FrontendConfig(kind="audio_frames", input_dim=32),
        dtype="float32", kv_chunk=64,
    )
