"""internlm2-1.8b [arXiv:2403.17297]. Assigned: 24L d2048 16H (kv=8)
d_ff=8192 vocab=92544, GQA."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", family="dense",
        n_layers=24, d_model=2048, vocab_size=92544,
        n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192,
        layer_pattern=("attn",),
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-smoke", family="dense",
        n_layers=2, d_model=64, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
        layer_pattern=("attn",),
        dtype="float32", kv_chunk=64,
    )
