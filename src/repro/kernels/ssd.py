"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid = (batch, heads, chunks); the chunk axis is sequential ("arbitrary"),
carrying the (head_dim × d_state) recurrent state in VMEM scratch — the state
never round-trips to HBM between chunks, which is the entire point of the
chunked SSD decomposition on TPU: the (L×L) intra-chunk matrix, the decay
cumsums, and the state all live in VMEM, and the three matmuls
(C·Bᵀ, M·X, Xᵀ·B) hit the MXU.

This is the hardware adaptation demanded by the assignment: the CUDA SSD
kernel tiles over thread blocks with shared-memory staging; here the same
block decomposition maps onto (VMEM tiles × MXU matmuls × sequential grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, h_scr, *,
                chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (c, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (c,)
    a = a_ref[0, 0]                            # scalar (negative)
    bm = b_ref[0].astype(jnp.float32)          # (c, N)
    cm = c_ref[0].astype(jnp.float32)          # (c, N)

    la = dt * a                                # (c,) log-decay per step
    cum = jnp.cumsum(la)                       # (c,)
    # intra-chunk: M_ij = (C_i·B_j) exp(cum_i - cum_j) dt_j, i >= j
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dec = jnp.exp(cum[:, None] - cum[None, :])
    m = jnp.where(ii >= jj, cb * dec * dt[None, :], 0.0)
    y = jax.lax.dot(m, x, preferred_element_type=jnp.float32)     # (c, P)
    # inter-chunk: y += exp(cum_i) C_i · h_prev
    h = h_scr[...]                                                # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (c, P)
    # state update: h = exp(cum_L) h + sum_j exp(cum_L - cum_j) dt_j x_j B_j^T
    w = (jnp.exp(cum[-1] - cum) * dt)[:, None] * x                # (c, P)
    h_scr[...] = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        w, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (P, N)
    o_ref[0, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
             cmat: jax.Array, *, chunk: int = 256,
             interpret: bool = False) -> jax.Array:
    """x: (B, S, H, P); dt: (B, S, H) post-softplus; a: (H,) negative;
    bmat/cmat: (B, S, N). Returns y (B, S, H, P). S must divide by chunk
    (callers pad)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xt = x.transpose(0, 2, 1, 3)                   # (B, H, S, P)
    dtt = dt.transpose(0, 2, 1)                    # (B, H, S)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (0, hi)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a.reshape(1, h), bmat, cmat)
    return out.transpose(0, 2, 1, 3)
