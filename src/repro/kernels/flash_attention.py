"""Pallas TPU flash attention (causal / sliding-window, GQA).

Tiling: grid = (batch, q_head, q_blocks, kv_blocks); the kv dimension is
``arbitrary`` (sequential), so the online-softmax running stats (m, l, acc)
live in VMEM scratch across kv steps. Block shapes keep the working set in
VMEM: q/k/v tiles are (block_q|block_k, head_dim) with head_dim padded to the
128-lane register width by Mosaic. GQA is expressed in the k/v index_map
(q head h reads kv head h // group_size) — no repeat/broadcast materializes.

Causal + window masks are applied with block-level skipping: fully-masked kv
blocks are skipped via ``pl.when`` around the whole body, so sliding-window
FLOPs scale with O(S·window) exactly like the banded XLA path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, n_k: int, seq_q: int,
                  seq_k: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level skip: is any (q, k) pair in this tile live?
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(
            live, (ki * block_k) <= (q_offset + qi * block_q + block_q - 1))
    if window is not None:
        live = jnp.logical_and(
            live, (ki * block_k + block_k - 1) > (q_offset + qi * block_q
                                                  - window))

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-37)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "q_offset",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    q_offset: int = 0,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D), H % K == 0 -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    n_q, n_k = sq_p // block_q, sk_p // block_k

    # layout: (B, H, S, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k, seq_q=sq, seq_k=sk,
        q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max
            pltpu.VMEM((block_q,), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :sq]
