"""jit'd dispatch wrappers: Pallas kernel on TPU (or under interpret=True),
pure-jnp reference elsewhere. The model stack calls these, so flipping
``ModelConfig.use_pallas`` swaps the hot paths in one place."""
from __future__ import annotations

import jax

from . import ref as _ref
from .flash_attention import flash_attention
from .ssd import ssd_scan
from .writhe import writhe_map


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=None, use_pallas=False,
              interpret=False):
    if use_pallas and (_on_tpu() or interpret):
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=interpret or not _on_tpu())
    return _ref.attention_ref(q, k, v, causal=causal, window=window)


def ssd(x, dt, a, bmat, cmat, *, chunk=256, use_pallas=False,
        interpret=False):
    if use_pallas and (_on_tpu() or interpret):
        return ssd_scan(x, dt, a, bmat, cmat, chunk=chunk,
                        interpret=interpret or not _on_tpu())
    return _ref.ssd_ref(x, dt, a, bmat, cmat, chunk=chunk)


def writhe(coords, *, block=128, use_pallas=False, interpret=False):
    if use_pallas and (_on_tpu() or interpret):
        return writhe_map(coords, block=block,
                          interpret=interpret or not _on_tpu())
    return _ref.writhe_map_ref(coords)
