"""Pallas TPU kernel for the Gauss-linking-integral writhe map — the paper's
computational workload, adapted to TPU.

AlphaKnot's pipeline (paper §4) computes topological invariants over protein
backbones; the knot-position heuristic needs per-segment-pair crossing
contributions (the *writhe map* W[i,j]), an O(n²) pairwise computation that
Topoly runs on GPU. The TPU adaptation tiles segment pairs into
(block_i × block_j) VMEM blocks; each grid cell evaluates the Klenin–Langowski
(2000) Gauss integral for its pair block with pure VPU element-wise math —
there is no reduction between blocks, so the grid is fully parallel.

W[i,j] = Ω_ij / 2π, the signed solid angle of segment pair (i, j); the total
writhe of subchain [a, b) is ``W[a:b, a:b].sum()`` — which is exactly what the
knot-core localization scan in ``repro.apps.knots`` consumes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cross(a, b):
    ax, ay, az = a
    bx, by, bz = b
    return (ay * bz - az * by, az * bx - ax * bz, ax * by - ay * bx)


def _dot(a, b):
    return a[0] * b[0] + a[1] * b[1] + a[2] * b[2]


def _norm(a, eps):
    n = jnp.sqrt(_dot(a, a) + eps)
    return (a[0] / n, a[1] / n, a[2] / n)


def _writhe_block(p1, p2, q1, q2, eps=1e-12):
    """Signed pair contribution for segment blocks.
    p1/p2: tuple of 3 arrays (bi, 1); q1/q2: (1, bj). Returns (bi, bj)."""
    r13 = tuple(q1[k] - p1[k] for k in range(3))
    r14 = tuple(q2[k] - p1[k] for k in range(3))
    r23 = tuple(q1[k] - p2[k] for k in range(3))
    r24 = tuple(q2[k] - p2[k] for k in range(3))
    n1 = _norm(_cross(r13, r14), eps)
    n2 = _norm(_cross(r14, r24), eps)
    n3 = _norm(_cross(r24, r23), eps)
    n4 = _norm(_cross(r23, r13), eps)

    def asin_clip(x):
        return jnp.arcsin(jnp.clip(x, -1.0, 1.0))

    omega = (asin_clip(_dot(n1, n2)) + asin_clip(_dot(n2, n3)) +
             asin_clip(_dot(n3, n4)) + asin_clip(_dot(n4, n1)))
    r12 = tuple(p2[k] - p1[k] for k in range(3))
    r34 = tuple(q2[k] - q1[k] for k in range(3))
    sign = jnp.sign(_dot(_cross(r34, r12), r13))
    return omega * sign / (4.0 * jnp.pi) * 2.0


def _writhe_kernel(s1_ref, s2_ref, t1_ref, t2_ref, o_ref, *, block: int):
    bi = pl.program_id(1)
    bj = pl.program_id(2)
    p1 = tuple(s1_ref[0, :, k][:, None] for k in range(3))  # (bi, 1)
    p2 = tuple(s2_ref[0, :, k][:, None] for k in range(3))
    q1 = tuple(t1_ref[0, :, k][None, :] for k in range(3))  # (1, bj)
    q2 = tuple(t2_ref[0, :, k][None, :] for k in range(3))
    w = _writhe_block(p1, p2, q1, q2)
    # adjacent/identical segments have no well-defined crossing: zero the
    # |i - j| <= 1 band.
    ii = bi * block + jax.lax.broadcasted_iota(jnp.int32, w.shape, 0)
    jj = bj * block + jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    w = jnp.where(jnp.abs(ii - jj) <= 1, 0.0, w)
    o_ref[0] = w.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def writhe_map(coords: jax.Array, *, block: int = 128,
               interpret: bool = False) -> jax.Array:
    """coords: (B, n_points, 3) backbone (e.g. Cα trace) ->
    writhe map (B, n_seg, n_seg) with n_seg = n_points - 1 (padded to a
    multiple of ``block``; pad segments are degenerate and contribute 0)."""
    b, npts, _ = coords.shape
    nseg = npts - 1
    s1 = coords[:, :-1]
    s2 = coords[:, 1:]
    pad = (-nseg) % block
    if pad:
        # repeat the last point: zero-length segments -> zero contribution
        last = s2[:, -1:]
        s1 = jnp.concatenate([s1, jnp.repeat(last, pad, 1)], axis=1)
        s2 = jnp.concatenate([s2, jnp.repeat(last, pad, 1)], axis=1)
    n = s1.shape[1]
    nb = n // block
    out = pl.pallas_call(
        functools.partial(_writhe_kernel, block=block),
        grid=(b, nb, nb),
        in_specs=[
            pl.BlockSpec((1, block, 3), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, block, 3), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, block, 3), lambda bi, i, j: (bi, j, 0)),
            pl.BlockSpec((1, block, 3), lambda bi, i, j: (bi, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, block),
                               lambda bi, i, j: (bi, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), jnp.float32),
        interpret=interpret,
    )(s1, s2, s1, s2)
    return out[:, :nseg, :nseg]
