"""Pallas TPU flash-decode: fused single-token attention for serving.

Decode is one query token per slot against a long KV cache — the serving
hot path. The kernel follows the split-KV flash-decode idiom: the grid is
``(batch, kv_head, kv_blocks)`` with the kv dimension sequential, and the
online-softmax running stats (m, l, acc) live in VMEM scratch across kv
steps exactly like ``flash_attention.py``. Because the grid is already per
KV head, the whole GQA group of query heads rides in one ``(G, D)`` block
and the group/KV matmul needs no repeat/broadcast.

Ragged continuous batching is expressed through two position inputs:

* ``q_positions`` (B,) — each slot's absolute decode position (scalar
  prefetch, read from SMEM);
* ``k_positions`` (B, S) — the absolute position held by each cache slot,
  with **-1 meaning invalid**. This one encoding covers dense prefixes
  (``arange`` masked at ``end``), ring buffers (slot ``j`` holds position
  ``t-1-((t-1-j) mod window)``; negatives = not yet written), padded slots
  and empty lanes, so the kernel needs no layout-specific masking.

Fully-masked kv blocks are skipped via ``pl.when`` around the body, so a
slot at position p does O(ceil(p/block_k)) work, not O(S_cache).

Three callables share the contract:

* :func:`flash_decode` — the Pallas kernel (TPU, or ``interpret=True``);
* :func:`flash_decode_xla` — the same split-KV online-softmax algorithm
  lowered through XLA with a *dynamic* trip count bounded by the furthest
  live position (``bounded=True``), the portable fast path on CPU/GPU;
* :func:`decode_attention` — backend dispatch between the two.

:func:`flash_decode_paged` / :func:`decode_attention_paged` are the paged
variants: KV lives in a physical page pool ``(P, page_size, K, D)`` and a
per-slot page table ``(B, pages_per_slot)`` (-1 = unbound) is scalar-
prefetched so the k/v ``index_map`` gathers pages directly — no logical
cache is ever materialized, and work scales with *bound pages*, not
``max_len``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# dense (contiguous cache) kernel
# ---------------------------------------------------------------------------


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float,
                   window: int | None, n_k: int):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qp = qpos_ref[bi]
    kp = kpos_ref[...]                       # (1, block_k) int32
    mask = (kp >= 0) & (kp <= qp)
    if window is not None:
        mask &= kp > qp - window

    @pl.when(jnp.any(mask))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, Dk)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, Dk)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)      # (G, block_k) via (1, bk) bcast
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)  # (block_k, Dv)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        # l == 0 (fully-masked slot, e.g. an empty lane) yields zeros, the
        # same convention as the chunked reference.
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-37)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "scale",
                                             "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 q_positions: jax.Array,
                 k_positions: jax.Array | None = None, *,
                 window: int | None = None, block_k: int = 128,
                 scale: float | None = None,
                 interpret: bool = False) -> jax.Array:
    """q: (B, 1, H, Dk); k: (B, S, K, Dk); v: (B, S, K, Dv) -> (B, 1, H, Dv).

    ``q_positions``: (B,) int32 absolute position of each slot's query.
    ``k_positions``: (B, S) int32 cache-slot positions, -1 = invalid;
    defaults to ``arange(S)`` (contiguous prefix cache).
    """
    b, sq, h, dk = q.shape
    assert sq == 1, "flash_decode is single-token-per-slot"
    _, s, kh, _ = k.shape
    dv = v.shape[-1]
    g = h // kh
    if scale is None:
        scale = 1.0 / math.sqrt(dk)
    if k_positions is None:
        k_positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    block_k = min(block_k, s)
    pad = (-s) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
    n_k = (s + pad) // block_k

    qt = q[:, 0].reshape(b, kh, g, dk)           # head h = kh*g + g_idx
    kt = k.transpose(0, 2, 1, 3)                 # (B, K, S, Dk)
    vt = v.transpose(0, 2, 1, 3)                 # (B, K, S, Dv)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               n_k=n_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, g, dk), lambda bi, hi, ki, qp: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_k, dk),
                         lambda bi, hi, ki, qp: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda bi, hi, ki, qp: (bi, hi, ki, 0)),
            pl.BlockSpec((1, block_k), lambda bi, hi, ki, qp: (bi, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda bi, hi, ki, qp: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),       # running max
            pltpu.VMEM((g,), jnp.float32),       # running sum
            pltpu.VMEM((g, dv), jnp.float32),    # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dv), q.dtype),
        interpret=interpret,
    )(q_positions.astype(jnp.int32), qt, kt, vt, k_positions)
    return out.reshape(b, 1, h, dv)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "scale",
                                             "bounded"))
def flash_decode_xla(q: jax.Array, k: jax.Array, v: jax.Array,
                     q_positions: jax.Array,
                     k_positions: jax.Array | None = None, *,
                     window: int | None = None, block_k: int = 128,
                     scale: float | None = None,
                     bounded: bool = True) -> jax.Array:
    """Same contract as :func:`flash_decode`, lowered through XLA.

    ``bounded=True`` (valid only when cache slot index == position, i.e.
    non-ring caches) runs the kv-block loop with a *dynamic* trip count
    ``ceil((max(q_positions)+1)/block_k)`` — per-step work scales with
    occupancy instead of cache capacity, which is where the long-context
    decode speedup over the full-cache chunked path comes from.
    """
    b, sq, h, dk = q.shape
    assert sq == 1
    _, s, kh, _ = k.shape
    dv = v.shape[-1]
    g = h // kh
    if scale is None:
        scale = 1.0 / math.sqrt(dk)
    if k_positions is None:
        k_positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    block_k = min(block_k, s)
    pad = (-s) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
    n_k = (s + pad) // block_k
    qp = q_positions.astype(jnp.int32)
    qh = q[:, 0].reshape(b, kh, g, dk).astype(jnp.float32)

    if bounded:
        n_live = jnp.clip((jnp.max(qp) + block_k) // block_k, 0, n_k)
    else:
        n_live = jnp.asarray(n_k, jnp.int32)

    def body(i, carry):
        m_run, l_run, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, i * block_k, block_k, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, i * block_k, block_k, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(k_positions, i * block_k, block_k,
                                          axis=1)
        sc = jnp.einsum("bkgd,bckd->bkgc", qh, kc.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * scale
        mask = (kp >= 0) & (kp <= qp[:, None])
        if window is not None:
            mask &= kp > qp[:, None] - window
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        m_new = jnp.maximum(m_run, sc.max(axis=-1))
        # mask p explicitly: in an all-invalid block m_new stays NEG_INF and
        # exp(NEG_INF - NEG_INF) = 1 would attend uniformly to garbage
        p = jnp.where(mask[:, None, None, :],
                      jnp.exp(sc - m_new[..., None]), 0.0)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgc,bckd->bkgd", p, vc.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        return m_new, l_new, acc * corr[..., None] + pv

    m0 = jnp.full((b, kh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g), jnp.float32)
    a0 = jnp.zeros((b, kh, g, dv), jnp.float32)
    _, l_f, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, a0))
    out = acc / jnp.maximum(l_f[..., None], 1e-37)
    return out.reshape(b, 1, h, dv).astype(q.dtype)


def decode_attention(q, k, v, q_positions, k_positions=None, *,
                     window=None, block_k=128, interpret=False,
                     bounded=True):
    """Backend dispatch: Pallas kernel on TPU (or under ``interpret=True``
    for parity tests), split-KV XLA lowering elsewhere."""
    if _on_tpu() or interpret:
        return flash_decode(q, k, v, q_positions, k_positions,
                            window=window, block_k=block_k,
                            interpret=interpret and not _on_tpu())
    return flash_decode_xla(q, k, v, q_positions, k_positions,
                            window=window, block_k=block_k, bounded=bounded)


# ---------------------------------------------------------------------------
# paged cache kernel
# ---------------------------------------------------------------------------


def _paged_kernel(qpos_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float,
                  window: int | None, page_size: int, n_pages: int):
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qp = qpos_ref[bi]
    page = table_ref[bi, pi]
    # pages are bound in logical order, so slot offsets map to positions
    # pi*page_size + offset directly; no per-slot position array needed.
    kp = pi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    mask = (kp <= qp) & (page >= 0)
    if window is not None:
        mask &= kp > qp - window

    @pl.when(jnp.any(mask))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # (G, Dk)
        k = k_ref[0, :, 0].astype(jnp.float32)        # (page_size, Dk)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        v = v_ref[0, :, 0].astype(jnp.float32)        # (page_size, Dv)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-37)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale", "interpret"))
def flash_decode_paged(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                       q_positions: jax.Array, page_table: jax.Array, *,
                       window: int | None = None, scale: float | None = None,
                       interpret: bool = False) -> jax.Array:
    """q: (B, 1, H, Dk); pool_k: (P, page_size, K, Dk); pool_v likewise with
    Dv; page_table: (B, pages_per_slot) int32, -1 = unbound (page 0 is the
    allocator's reserved trash page). -> (B, 1, H, Dv).

    The page table is scalar-prefetched so the k/v ``index_map`` gathers the
    physical page per grid step — unbound entries clamp to page 0 and are
    masked out by ``page >= 0`` inside the kernel.
    """
    b, sq, h, dk = q.shape
    assert sq == 1
    _, page_size, kh, _ = pool_k.shape
    dv = pool_v.shape[-1]
    g = h // kh
    n_pages = page_table.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(dk)
    qt = q[:, 0].reshape(b, kh, g, dk)

    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               page_size=page_size, n_pages=n_pages)

    def kv_map(bi, hi, pi, qp, table):
        return (jnp.maximum(table[bi, pi], 0), 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, dk),
                         lambda bi, hi, pi, qp, tb: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dk), kv_map),
            pl.BlockSpec((1, page_size, 1, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda bi, hi, pi, qp, tb: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dv), q.dtype),
        interpret=interpret,
    )(q_positions.astype(jnp.int32), page_table.astype(jnp.int32),
      qt, pool_k, pool_v)
    return out.reshape(b, 1, h, dv)


@functools.partial(jax.jit, static_argnames=("window", "scale", "bounded"))
def flash_decode_paged_xla(q: jax.Array, pool_k: jax.Array,
                           pool_v: jax.Array, q_positions: jax.Array,
                           page_table: jax.Array, *,
                           window: int | None = None,
                           scale: float | None = None,
                           bounded: bool = True) -> jax.Array:
    """Paged decode through XLA: a dynamic-trip-count loop over page blocks,
    gathering one physical page per slot per step. Work scales with bound
    pages (occupancy), never materializing the logical cache."""
    b, sq, h, dk = q.shape
    assert sq == 1
    _, page_size, kh, _ = pool_k.shape
    dv = pool_v.shape[-1]
    g = h // kh
    n_pages = page_table.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(dk)
    qp = q_positions.astype(jnp.int32)
    qh = q[:, 0].reshape(b, kh, g, dk).astype(jnp.float32)
    table = page_table.astype(jnp.int32)
    if bounded:
        n_live = jnp.clip((jnp.max(qp) + page_size) // page_size, 0, n_pages)
    else:
        n_live = jnp.asarray(n_pages, jnp.int32)

    def body(i, carry):
        m_run, l_run, acc = carry
        pages = jax.lax.dynamic_slice_in_dim(table, i, 1, axis=1)[:, 0]
        kc = pool_k[jnp.maximum(pages, 0)]     # (B, page_size, K, Dk)
        vc = pool_v[jnp.maximum(pages, 0)]
        kp = i * page_size + jnp.arange(page_size, dtype=jnp.int32)[None, :]
        mask = (pages >= 0)[:, None] & (kp <= qp[:, None])
        if window is not None:
            mask &= kp > qp[:, None] - window
        sc = jnp.einsum("bkgd,bckd->bkgc", qh, kc.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * scale
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        m_new = jnp.maximum(m_run, sc.max(axis=-1))
        # mask p explicitly: in an all-invalid block m_new stays NEG_INF and
        # exp(NEG_INF - NEG_INF) = 1 would attend uniformly to garbage
        p = jnp.where(mask[:, None, None, :],
                      jnp.exp(sc - m_new[..., None]), 0.0)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgc,bckd->bkgd", p, vc.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        return m_new, l_new, acc * corr[..., None] + pv

    m0 = jnp.full((b, kh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g), jnp.float32)
    a0 = jnp.zeros((b, kh, g, dv), jnp.float32)
    _, l_f, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, a0))
    out = acc / jnp.maximum(l_f[..., None], 1e-37)
    return out.reshape(b, 1, h, dv).astype(q.dtype)


def decode_attention_paged(q, pool_k, pool_v, q_positions, page_table, *,
                           window=None, interpret=False):
    """Backend dispatch for the paged cache path."""
    if _on_tpu() or interpret:
        return flash_decode_paged(q, pool_k, pool_v, q_positions, page_table,
                                  window=window,
                                  interpret=interpret and not _on_tpu())
    return flash_decode_paged_xla(q, pool_k, pool_v, q_positions, page_table,
                                  window=window)
