"""Pallas TPU kernels for the perf-critical hot spots, each with a jit'd
dispatch wrapper (ops.py) and a pure-jnp oracle (ref.py):

* ``flash_attention`` — causal/sliding-window GQA, online softmax, VMEM
  block tiling with causal/window block skipping;
* ``flash_decode``    — the serving decode path: single-token-per-slot
  split-KV attention with per-slot ragged positions, ring/window masking
  and a paged-KV variant (page table in scalar prefetch);
* ``ssd``             — Mamba-2 chunked SSD scan, recurrent state in VMEM
  scratch across the sequential chunk grid;
* ``writhe``          — the paper's workload: Gauss-linking writhe map over
  segment-pair blocks (AlphaKnot's knot screen / knot-core heuristic).
"""
from . import flash_decode, ops, ref

__all__ = ["flash_decode", "ops", "ref"]
