"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

``attention_ref`` / ``ssd_ref`` delegate to the model-stack implementations
(which are themselves validated against naive math in the model tests), so
kernels, models, and refs form one consistency triangle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention
from repro.models.ssd import ssd_chunked


def attention_ref(q, k, v, *, causal=True, window=None):
    return chunked_attention(q, k, v, causal=causal, window=window,
                             kv_chunk=max(int(k.shape[1]), 1))


def ssd_ref(x, dt, a, bmat, cmat, *, chunk=64):
    y, _ = ssd_chunked(x, dt, a, bmat, cmat, chunk=chunk)
    return y


def writhe_map_ref(coords: jax.Array) -> jax.Array:
    """coords: (B, n, 3) -> (B, n-1, n-1) Gauss-integral writhe map
    (Klenin–Langowski method 1a), straightforward broadcast implementation."""
    p1 = coords[:, :-1, None, :]   # (B, i, 1, 3)
    p2 = coords[:, 1:, None, :]
    q1 = coords[:, None, :-1, :]   # (B, 1, j, 3)
    q2 = coords[:, None, 1:, :]
    r13 = q1 - p1
    r14 = q2 - p1
    r23 = q1 - p2
    r24 = q2 - p2

    def norm(x):
        return x / jnp.sqrt((x * x).sum(-1, keepdims=True) + 1e-12)

    n1 = norm(jnp.cross(r13, r14))
    n2 = norm(jnp.cross(r14, r24))
    n3 = norm(jnp.cross(r24, r23))
    n4 = norm(jnp.cross(r23, r13))

    def asin_dot(a, b):
        return jnp.arcsin(jnp.clip((a * b).sum(-1), -1.0, 1.0))

    omega = (asin_dot(n1, n2) + asin_dot(n2, n3) +
             asin_dot(n3, n4) + asin_dot(n4, n1))
    sign = jnp.sign((jnp.cross(q2 - q1, p2 - p1) * r13).sum(-1))
    w = omega * sign / (4.0 * jnp.pi) * 2.0
    nseg = w.shape[1]
    ii = jnp.arange(nseg)[:, None]
    jj = jnp.arange(nseg)[None, :]
    return jnp.where(jnp.abs(ii - jj) <= 1, 0.0, w)
