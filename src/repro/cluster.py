"""KsaCluster — the public facade over the KSA control plane.

Every example and benchmark used to hand-wire five components (Broker +
Submitter + Worker/Cluster agents + MonitorAgent + PipelineAgent) and had to
keep their prefixes, poll intervals, and placement policies consistent by
convention. ``KsaCluster`` owns that wiring: one object builds the broker and
topics, starts the agent pools (CPU workers, GPU workers, simulated Slurm
clusters), runs the monitor (+ optional REST API) and a lazily-started
pipeline agent, and tears everything down in reverse order on exit::

    from repro.cluster import KsaCluster

    with KsaCluster(workers=2, gpu_workers=1,
                    slurm=dict(nodes=2, cpus_per_node=4)) as c:
        tid = c.submit("matrix", params={"n": 96}, timeout_s=60.0)
        c.wait_all([tid])
        print(c.result(tid))
        res = c.run_campaign(spec, items)       # DAG campaigns too
        print(c.status())                       # one aggregated snapshot

Placement is wired once: the facade passes the same
:class:`~repro.core.scheduling.PlacementPolicy` to the submitter, every
agent, the monitor, and the pipeline agent, so GPU stages route to the GPU
pool end to end (the ParaFold split). Direct component wiring remains
available for tests and embedders, but is considered internal API.
"""
from __future__ import annotations

import threading
import time
from typing import TYPE_CHECKING, Any, Callable, Iterable, Mapping

from repro.core.broker import Broker
from repro.core.agents import AgentBase, ClusterAgent, WorkerAgent
from repro.core.lease import RevokeReason
from repro.core.messages import topic_names
from repro.core.monitor import MonitorAgent, TaskEntry
from repro.core.scheduling import (LeasePolicy, PlacementPolicy,
                                   ResourceClassPolicy, ResourceProfile)
from repro.core.simslurm import SimSlurm
from repro.core.submitter import Submitter
from repro.obs import (AlertEngine, AlertRule, SloSpec, TelemetryCollector,
                       TelemetryPublisher, TimeSeriesStore)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.autoscale import AutoscaleConfig, AutoscaleController

_SLURM_KEYS = ("nodes", "cpus_per_node", "gpus_per_node", "mem_mb_per_node",
               "scheduler_interval_s", "spinup_s")

_CPU_DEFAULT = object()  # add_worker sentinel: "cpu-only profile sized to slots"


class KsaCluster:
    """Context-managed KSA deployment: broker, agent pools, monitor,
    pipeline orchestration, and one placement policy wired through all of
    them.

    Declarative pools: ``workers`` CPU-only workers (``worker_slots`` each),
    ``gpu_workers`` GPU-capable workers (``gpu_slots`` each), and ``slurm`` —
    a :class:`SimSlurm`, or a dict of SimSlurm kwargs (plus ClusterAgent
    kwargs such as ``oversubscribe``), or ``None``. More pools can be added
    after :meth:`start` with :meth:`add_worker` / :meth:`add_slurm`, removed
    gracefully with :meth:`drain_worker`, or managed *elastically* by
    passing ``autoscale=AutoscaleConfig(...)`` (see :mod:`repro.autoscale`):
    a controller then grows/shrinks per-resource-class pools from the class
    topics' queue depth, and the monitor serves its decisions and backlog
    history on ``/autoscale``.

    ``broker=None`` creates (and owns, i.e. closes) an embedded broker;
    passing one shares it and leaves its lifecycle to the caller.
    """

    def __init__(self, *, prefix: str = "ksa",
                 broker: Broker | None = None,
                 placement: PlacementPolicy | None = None,
                 lease: LeasePolicy | None = None,
                 workers: int = 0, worker_slots: int = 2,
                 gpu_workers: int = 0, gpu_slots: int = 1,
                 slurm: SimSlurm | Mapping[str, Any] | None = None,
                 autoscale: "AutoscaleConfig | None" = None,
                 monitor: bool = True,
                 http: bool = False,
                 task_timeout_s: float | None = None,
                 max_attempts: int = 3,
                 pipeline_task_timeout_s: float | None = None,
                 pipeline_journal: bool = True,
                 max_in_flight_total: int | None = None,
                 compact_interval_s: float | None = None,
                 compact_every_events: int | None = None,
                 poll_interval_s: float = 0.01,
                 session_timeout_s: float | None = None,
                 default_partitions: int = 4,
                 partitioner: str = "hash",
                 obs: bool = True,
                 telemetry: bool = False,
                 telemetry_interval_s: float = 0.25,
                 slos: Iterable[SloSpec | AlertRule] = (),
                 site: str = "",
                 single_lock: bool = False,
                 debug_locks: bool = False,
                 agent_kw: Mapping[str, Any] | None = None,
                 monitor_kw: Mapping[str, Any] | None = None):
        self.prefix = prefix
        # federation: which site this control plane is ("" = standalone);
        # tags the owned broker so its stats and leases carry the site
        self.site = site
        self.placement = placement or ResourceClassPolicy()
        self._lease = lease
        self._spec = dict(workers=workers, worker_slots=worker_slots,
                          gpu_workers=gpu_workers, gpu_slots=gpu_slots,
                          slurm=slurm)
        self._autoscale_cfg = autoscale
        self._monitor_enabled = monitor
        self._http = http
        self.task_timeout_s = task_timeout_s
        self.max_attempts = max_attempts
        self.pipeline_task_timeout_s = pipeline_task_timeout_s
        self.pipeline_journal = pipeline_journal
        self.max_in_flight_total = max_in_flight_total
        # scheduled journal compaction (ROADMAP open item): with either knob
        # set, the monitor loop runs pipeline compact() on a period and/or
        # whenever that many new journal events have been ingested.
        self.compact_interval_s = compact_interval_s
        self.compact_every_events = compact_every_events
        self.poll_interval_s = poll_interval_s
        self.partitioner = partitioner
        self._agent_kw = dict(agent_kw or {})
        self._monitor_kw = dict(monitor_kw or {})

        self._owns_broker = broker is None
        if broker is None:
            # single_lock / debug_locks pass straight through to the owned
            # broker's data plane (legacy escape hatch / lock-order checks)
            broker_kw: dict[str, Any] = {"default_partitions": default_partitions,
                                         "obs": obs, "site": site,
                                         "single_lock": single_lock,
                                         "debug_locks": debug_locks}
            if session_timeout_s is not None:
                broker_kw["session_timeout_s"] = session_timeout_s
            broker = Broker(**broker_kw)
        self.broker = broker

        # telemetry plane (ISSUE 9, opt-in): periodic metric/span/event
        # snapshots on the durable PREFIX-telemetry topic, folded into a
        # queryable TimeSeriesStore and burn-rate-alerted against `slos`
        self._telemetry_enabled = telemetry
        self._telemetry_interval_s = telemetry_interval_s
        self._slos = tuple(slos)
        self.telemetry_store: TimeSeriesStore | None = None
        self.telemetry_publisher: TelemetryPublisher | None = None
        self.telemetry_collector: TelemetryCollector | None = None
        self.alert_engine: AlertEngine | None = None

        self.agents: list[AgentBase] = []
        self._slurms: list[SimSlurm] = []     # owned simulated clusters
        self.monitor: MonitorAgent | None = None
        self.autoscaler: "AutoscaleController | None" = None
        self.submitter: Submitter | None = None
        self._pipeline = None                 # lazy PipelineAgent
        self._http_port: int | None = None
        self._lock = threading.RLock()
        self._started = False
        self._stopped = False

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "KsaCluster":
        """Build and start every owned component. Raises on double-start —
        one facade is one deployment; make a second KsaCluster (sharing the
        broker) for a second deployment."""
        with self._lock:
            if self._stopped:
                raise RuntimeError(
                    f"KsaCluster(prefix={self.prefix!r}) was stopped; "
                    f"create a new instance")
            if self._started:
                raise RuntimeError(
                    f"KsaCluster(prefix={self.prefix!r}) already started")
            self._started = True
            try:
                self.submitter = Submitter(self.broker, self.prefix,
                                           placement=self.placement,
                                           partitioner=self.partitioner)
                # flight-recorder dumps carry live control-plane context
                self.broker.blackbox.context_fn = self._blackbox_context
                if self._telemetry_enabled:
                    self._start_telemetry()
                if self._monitor_enabled:
                    kw = dict(task_timeout_s=self.task_timeout_s,
                              max_attempts=self.max_attempts,
                              poll_interval_s=self.poll_interval_s,
                              placement=self.placement)
                    kw.update(self._monitor_kw)
                    self.monitor = MonitorAgent(self.broker, self.prefix,
                                                **kw).start()
                    if self._http:
                        self._http_port = self.monitor.start_http(0)
                    if self.compact_interval_s is not None or \
                            self.compact_every_events is not None:
                        self.monitor.attach_compaction(
                            self._auto_compact,
                            interval_s=self.compact_interval_s,
                            every_events=self.compact_every_events)
                    if self.telemetry_collector is not None:
                        self.monitor.attach_telemetry(
                            self.telemetry_collector, self.alert_engine,
                            interval_s=self._telemetry_interval_s)
                for _ in range(self._spec["workers"]):
                    self.add_worker(slots=self._spec["worker_slots"])
                for _ in range(self._spec["gpu_workers"]):
                    self.add_worker(slots=self._spec["gpu_slots"],
                                    profile=ResourceProfile(
                                        cpus=self._spec["gpu_slots"], gpus=1,
                                        mem_mb=1024 * self._spec["gpu_slots"]))
                if self._spec["slurm"] is not None:
                    self.add_slurm(self._spec["slurm"])
                if self._autoscale_cfg is not None:
                    from repro.autoscale import AutoscaleController
                    self.autoscaler = AutoscaleController(
                        self, self._autoscale_cfg).start()
                    if self.monitor is not None:
                        self.monitor.attach_autoscale(self.autoscaler.status)
            except BaseException:
                # unwind whatever already started (threads, owned broker) —
                # a failed __enter__ never reaches __exit__
                self.stop()
                raise
        return self

    def _start_telemetry(self) -> None:
        """Build the telemetry plane: store + collector + alert engine +
        publisher, all sharing the durable ``PREFIX-telemetry`` topic.
        Called under the facade lock from :meth:`start`, before the
        autoscaler is built so its sensing lands in the same store."""
        topic = topic_names(self.prefix)["telemetry"]
        self.telemetry_store = TimeSeriesStore()
        self.telemetry_collector = TelemetryCollector(
            self.broker, topic, store=self.telemetry_store, site=self.site)
        rules = [r if isinstance(r, AlertRule) else AlertRule(slo=r)
                 for r in self._slos]
        self.alert_engine = AlertEngine(
            self.telemetry_store, rules, registry=self.broker.metrics,
            on_fire=self._on_alert_fire)
        self.telemetry_publisher = TelemetryPublisher(
            self.broker, topic, source=self.site or self.prefix,
            site=self.site, interval_s=self._telemetry_interval_s)
        self.telemetry_publisher.start()

    def _blackbox_context(self) -> dict:
        """Live control-plane context stitched into every flight-recorder
        dump: the unified lease ledger plus whatever alerts are firing."""
        ctx: dict[str, Any] = {"leases": self.broker.lease_stats()}
        engine = self.alert_engine
        if engine is not None:
            ctx["alerts"] = engine.active()
        return ctx

    def _on_alert_fire(self, rule: str, ev: dict) -> None:
        """Alert-engine hook: a firing alert is a trigger condition — it
        is recorded as a lifecycle event and latches a post-mortem dump."""
        self.broker.blackbox.record(
            "alert", rule=rule, burn_long=ev.get("burn_long"),
            burn_short=ev.get("burn_short"), metric=ev.get("metric"))
        self.broker.blackbox.dump(f"alert:{rule}", {"evaluation": ev})

    def stop(self, timeout: float = 5.0) -> None:
        """Graceful, idempotent teardown in reverse dependency order:
        autoscaler first (stop resizing pools), then the pipeline agent
        (stop emitting tasks), the agent pools (drain in-flight work so it
        is redelivered), monitor, owned Slurm simulators, and finally the
        broker if this facade created it."""
        with self._lock:
            if not self._started or self._stopped:
                self._stopped = True
                return
            self._stopped = True
            autoscaler = self.autoscaler
        if autoscaler is not None:
            autoscaler.stop(timeout=timeout)
        with self._lock:
            pipeline, agents = self._pipeline, list(self.agents)
            monitor, slurms = self.monitor, list(self._slurms)
        if pipeline is not None:
            pipeline.stop(timeout=timeout)
        for a in agents:
            a.stop(timeout=timeout)
        publisher = self.telemetry_publisher
        if publisher is not None:
            # final flush before the monitor (and broker) go away
            publisher.stop(timeout=timeout)
        if monitor is not None:
            monitor.stop(timeout=timeout)
        for s in slurms:
            s.shutdown()
        if self._owns_broker:
            self.broker.close()

    def __enter__(self) -> "KsaCluster":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    @property
    def started(self) -> bool:
        return self._started and not self._stopped

    def _require_started(self) -> None:
        if not self.started:
            raise RuntimeError(
                f"KsaCluster(prefix={self.prefix!r}) is not running — use "
                f"`with KsaCluster(...) as c:` or call start()")

    # -- agent pools -----------------------------------------------------------

    def add_worker(self, *, profile: ResourceProfile | None = _CPU_DEFAULT,
                   slots: int = 2, **kw: Any) -> WorkerAgent:
        """Start one in-process worker. By default the worker is CPU-only
        (GPU stages never route to it) with a memory budget of 1 GB per slot
        (mem-aware admission packs against it; default-sized tasks pack
        exactly one per slot); pass a GPU-capable or tainted
        :class:`ResourceProfile` for a model-owning/exclusive pool, or
        ``profile=None`` for a legacy universal worker that leases every
        class and skips memory admission."""
        self._require_started()
        if profile is _CPU_DEFAULT:
            profile = ResourceProfile(cpus=slots, mem_mb=1024 * slots)
        merged = dict(poll_interval_s=self.poll_interval_s, **self._agent_kw)
        merged.update(kw)
        agent = WorkerAgent(self.broker, self.prefix, slots=slots,
                            profile=profile, placement=self.placement,
                            **merged).start()
        with self._lock:
            self.agents.append(agent)
        return agent

    def add_slurm(self, slurm: SimSlurm | Mapping[str, Any] | None = None,
                  **kw: Any) -> ClusterAgent:
        """Attach a (simulated) Slurm cluster behind a ClusterAgent. Accepts
        a live :class:`SimSlurm` or a kwargs mapping — SimSlurm keys build the
        simulator (owned, shut down on exit); everything else (e.g.
        ``oversubscribe``) goes to the agent. The agent's resource profile is
        derived from the cluster hardware."""
        self._require_started()
        if slurm is None:
            slurm = {}
        if isinstance(slurm, Mapping):
            cfg = dict(slurm)
            cfg.update(kw)
            sim_kw = {k: cfg.pop(k) for k in _SLURM_KEYS if k in cfg}
            sim = SimSlurm(**sim_kw)
            with self._lock:
                self._slurms.append(sim)
            kw = cfg
        else:
            sim = slurm
        merged = dict(poll_interval_s=self.poll_interval_s, **self._agent_kw)
        merged.update(kw)
        agent = ClusterAgent(self.broker, sim, self.prefix,
                             placement=self.placement, **merged).start()
        with self._lock:
            self.agents.append(agent)
        return agent

    def drain_worker(self, agent: AgentBase, *,
                     timeout_s: float | None = None,
                     wait: bool = True) -> bool:
        """Gracefully remove one agent from the deployment (the manual
        counterpart of an autoscale scale-down): the agent stops its
        subscriptions (consumer-group leave — unread partitions rebalance
        to the survivors), requeues its deferred leases, lets in-flight
        tasks finish, then is deregistered. With ``wait=False`` the drain
        proceeds in the background (poll ``agent.state``) and a reaper
        deregisters the agent once it stops; otherwise blocks until drained
        and returns True, or False on ``timeout_s``."""
        agent.request_drain(timeout_s=timeout_s)
        if not wait:
            threading.Thread(
                target=self._await_drained, args=(agent, None),
                name=f"drain-reaper-{agent.agent_id}", daemon=True).start()
            return False
        deadline = None if timeout_s is None else \
            time.time() + timeout_s + 5.0
        return self._await_drained(agent, deadline)

    def _await_drained(self, agent: AgentBase,
                       deadline: float | None) -> bool:
        while agent.alive and not self._stopped:
            if deadline is not None and time.time() > deadline:
                return False
            time.sleep(0.01)
        if not agent.alive:
            self._forget_agent(agent)
        return not agent.alive

    def _forget_agent(self, agent: AgentBase) -> None:
        """Deregister a stopped agent (and shut down its owned SimSlurm)."""
        own_slurm = None
        with self._lock:
            if agent in self.agents:
                self.agents.remove(agent)
            slurm = getattr(agent, "slurm", None)
            if slurm is not None and slurm in self._slurms:
                self._slurms.remove(slurm)
                own_slurm = slurm
        if own_slurm is not None:
            own_slurm.shutdown()

    # -- flat task API ---------------------------------------------------------

    def submit(self, script: str, **kw: Any) -> str:
        self._require_started()
        return self.submitter.submit(script, **kw)

    def submit_batches(self, script: str, items: Any, **kw: Any) -> list[str]:
        self._require_started()
        return self.submitter.submit_batches(script, items, **kw)

    def wait_all(self, task_ids: list[str], timeout: float = 60.0,
                 poll: float = 0.02) -> bool:
        self._require_started()
        if self.monitor is None:
            raise RuntimeError("KsaCluster was built with monitor=False")
        return self.monitor.wait_all(task_ids, timeout=timeout, poll=poll)

    def task(self, task_id: str) -> TaskEntry | None:
        self._require_started()
        if self.monitor is None:
            raise RuntimeError("KsaCluster was built with monitor=False")
        return self.monitor.task(task_id)

    def result(self, task_id: str) -> dict | None:
        e = self.task(task_id)
        return None if e is None else e.result

    # -- campaigns (repro.pipeline) --------------------------------------------

    @property
    def pipeline(self):
        """The facade's PipelineAgent, started on first use (campaigns are
        optional; flat deployments never pay for the extra consumer)."""
        self._require_started()
        with self._lock:
            if self._pipeline is None:
                from repro.pipeline import PipelineAgent
                self._pipeline = PipelineAgent(
                    self.broker, self.prefix,
                    poll_interval_s=self.poll_interval_s,
                    default_task_timeout_s=self.pipeline_task_timeout_s,
                    placement=self.placement, lease=self._lease,
                    max_in_flight_total=self.max_in_flight_total,
                    journal=self.pipeline_journal).start()
            return self._pipeline

    def submit_campaign(self, spec: Any, items: Iterable | None = None, *,
                        params: Mapping[str, Any] | None = None,
                        campaign_id: str | None = None,
                        weight: float = 1.0) -> str:
        return self.pipeline.submit_campaign(spec, items, params=params,
                                             campaign_id=campaign_id,
                                             weight=weight)

    def run_campaign(self, spec: Any, items: Iterable | None = None, *,
                     params: Mapping[str, Any] | None = None,
                     weight: float = 1.0,
                     progress: Callable[[Any], None] | None = None,
                     timeout_s: float = 600.0):
        """Submit a campaign and block until its DAG drains; returns the
        :class:`~repro.pipeline.driver.CampaignResult`."""
        from repro.pipeline import run_campaign as _run
        return _run(spec, items, broker=self.broker, prefix=self.prefix,
                    params=params, agent=self.pipeline, weight=weight,
                    progress=progress, timeout_s=timeout_s)

    def recover(self, specs: Any, *, include_finished: bool = False
                ) -> list[str]:
        """Rebuild campaigns from the ``PREFIX-campaigns`` journal after an
        orchestrator crash (e.g. the previous KsaCluster process was
        ``kill -9``'d mid-campaign against a shared/durable broker).

        ``specs`` maps pipeline names to :class:`~repro.pipeline.PipelineSpec`
        (or is an iterable of specs) — campaign specs are code (scripts,
        ``skip_when`` predicates), so they are re-supplied rather than
        journaled. Every live campaign is folded from its journal, repaired,
        and resumed: tasks with no terminal event are resubmitted on a
        journaled retry budget, results produced while no orchestrator was
        alive are absorbed, and duplicates are re-fenced against the replayed
        state. Returns the recovered campaign ids; follow with
        :meth:`wait_campaign` / :meth:`campaign_status` as usual.
        ``include_finished=True`` also rebuilds terminal campaigns so their
        results can be re-read."""
        self._require_started()
        return self.pipeline.recover(specs, include_finished=include_finished)

    def compact(self, specs: Any = None) -> dict:
        """Compact the campaign journal: snapshot terminal campaigns into
        single ``CampaignSnapshot`` records and truncate their per-event
        history off the ``PREFIX-campaigns`` topic, so a long-lived
        deployment serving a stream of campaigns stays bounded. With
        ``specs`` (name → :class:`~repro.pipeline.PipelineSpec`), terminal
        campaigns already evicted from memory are folded from the journal
        and compacted too. See :meth:`~repro.pipeline.PipelineAgent.compact`."""
        return self.pipeline.compact(specs)

    def _auto_compact(self) -> dict | None:
        """Scheduled-compaction callback run from the monitor loop; a no-op
        (None) until a pipeline agent exists — flat deployments never
        compact, and never pay for a pipeline consumer either."""
        with self._lock:
            pipeline = self._pipeline
        if pipeline is None or self._stopped:
            return None
        return pipeline.compact()

    # -- lease lifecycle --------------------------------------------------------

    def revoke(self, task_id: str, reason: str = RevokeReason.SCANCEL, *,
               requeue: bool | None = None) -> bool:
        """Operator-facing ``scancel`` analogue: revoke a task's live lease
        through :meth:`~repro.core.broker.Broker.revoke_lease` — the holder
        is cancelled, its commit fenced, and the task requeued onto its
        class topic for another pool to pick up. ``requeue=None`` (default)
        applies the same split as every internal stop-path: flat tasks are
        broker-requeued; campaign tasks are only cancelled+fenced, and the
        owning PipelineAgent resubmits them on its journaled ``RetryPolicy``
        (a broker requeue behind its back would race its watchdog into a
        double execution). Returns False if the task holds no live lease
        (finished, or not yet leased)."""
        self._require_started()
        if requeue is None:
            view = self.broker.lease_view(task_id)
            requeue = view is None or view.get("campaign_id") is None
        return self.broker.revoke_lease(task_id, reason, requeue=requeue)

    def campaign_status(self, campaign_id: str):
        return self.pipeline.status(campaign_id)

    def wait_campaign(self, campaign_id: str, timeout: float = 60.0):
        return self.pipeline.wait(campaign_id, timeout=timeout)

    # -- observability ---------------------------------------------------------

    @property
    def http_port(self) -> int | None:
        """Port of the monitor REST API (``http=True``), else None."""
        return self._http_port

    def status(self) -> dict:
        """One aggregated snapshot: agents, monitor summary, campaigns,
        broker topic/group stats."""
        self._require_started()
        with self._lock:
            agents = [a.stats() for a in self.agents]
            pipeline = self._pipeline
        out: dict[str, Any] = {
            "prefix": self.prefix,
            "started": self.started,
            "agents": agents,
            "broker": self.broker.stats(),
            # unified stop-path telemetry: grants, completions, and
            # revocations by reason (watchdog / preempt / mem_overage /
            # drain / scancel) across every pool and campaign
            "leases": self.broker.lease_stats(),
        }
        if self.monitor is not None:
            out["monitor"] = self.monitor.summary()
        if pipeline is not None:
            out["campaigns"] = {c: s.to_dict()
                                for c, s in pipeline.campaigns().items()}
            out["preemptions"] = pipeline.preemptions
        if self.autoscaler is not None:
            out["autoscale"] = self.autoscaler.status()
        if self.alert_engine is not None:
            out["alerts"] = self.alert_engine.active()
        return out

    def query(self, name: str, *, agg: str = "latest",
              labels: dict[str, str] | None = None, window_s: float = 60.0,
              q: float | None = None, by: str | None = None) -> dict:
        """Query the telemetry time-series store — same semantics the
        monitor serves at ``GET /query``. ``agg`` is one of ``latest``,
        ``rate``, ``quantile`` (pass ``q``), ``sum_by`` (pass ``by``),
        ``sum`` or ``points``. Requires ``telemetry=True``; in a federation
        the home store carries ``site``-labelled series from every feed,
        so ``agg="sum_by", by="site"`` answers across sites."""
        store = self.telemetry_store
        if store is None:
            raise RuntimeError(
                "telemetry plane is off; construct KsaCluster(telemetry=True)")
        # poll eagerly so a query right after an event sees it without
        # waiting for the monitor's telemetry tick
        if self.telemetry_collector is not None:
            self.telemetry_collector.poll()
        return store.query(name, agg=agg, labels=labels,
                           window_s=window_s, q=q, by=by)

    def alerts(self) -> dict:
        """SLO alert-engine status: per-rule state, firing set, history."""
        if self.alert_engine is None:
            raise RuntimeError(
                "no alert engine; construct KsaCluster(telemetry=True)")
        if self.telemetry_collector is not None:
            self.telemetry_collector.poll()
        self.alert_engine.evaluate()
        return self.alert_engine.status()

    def dump_blackbox(self, trigger: str = "manual") -> dict:
        """Force a flight-recorder post-mortem dump and return it. Works
        with or without the telemetry plane — the blackbox rides on the
        broker and records lifecycle events unconditionally."""
        return self.broker.blackbox.dump(trigger)

    def metrics_text(self) -> str:
        """Prometheus text-format snapshot of the broker's metrics registry
        — the same payload the monitor serves at ``GET /metrics``."""
        return self.broker.metrics.render()

    def trace(self, task_id: str) -> list[dict]:
        """Full span chain for a task, sorted by start time: ``submit``,
        ``route``, ``grant`` (duration = queue wait), ``claim``, ``run``,
        ``revoke``, ``commit``, and ``journal`` spans across every attempt
        (attempts share one ``trace_id``, so a preempted-and-retried task
        yields one linked chain). Empty list if the task is unknown, its
        spans were evicted from the bounded store, or ``obs=False``."""
        return self.broker.spans.trace(task_id)

    def campaign_report(self, campaign_id: str) -> dict:
        """Per-stage critical-path breakdown for a campaign, joined from the
        span store: where wall-clock went — queue wait vs run time vs time
        burnt on pre-terminal attempts (retries/preemptions).

        Per stage (topological order): ``queue_s``/``run_s`` sum the
        terminal attempt's grant/run span durations across its tasks,
        ``retry_s`` sums wall time spent inside earlier attempts, ``retries``
        counts non-terminal attempts, ``wall_s`` is the stage's span extent
        (first span start → last span end). ``dominant_stage`` names the
        stage with the largest wall_s."""
        st = self.pipeline.status(campaign_id)
        stages: dict[str, dict] = {}
        for stage_name, task_ids in self.pipeline.stage_tasks(campaign_id):
            agg = {"tasks": len(task_ids), "traced": 0, "queue_s": 0.0,
                   "run_s": 0.0, "retry_s": 0.0, "retries": 0, "wall_s": 0.0}
            lo, hi = None, None
            for tid in task_ids:
                spans = self.broker.spans.trace(tid)
                if not spans:
                    continue
                agg["traced"] += 1
                lo = min(lo, spans[0]["start"]) if lo is not None else spans[0]["start"]
                end = max(s["end"] for s in spans)
                hi = max(hi, end) if hi is not None else end
                # terminal attempt = the attempt of the last run span (the
                # one whose result actually committed); everything before
                # it is retry overhead.
                runs = [s for s in spans if s["name"] == "run"]
                term = runs[-1]["attempt"] if runs else None
                for s in spans:
                    if s["name"] == "grant" and s.get("attempt") == term:
                        agg["queue_s"] += s["dur_s"]
                    elif s["name"] == "run" and s.get("attempt") == term:
                        agg["run_s"] += s["dur_s"]
                if term is not None:
                    earlier = [s for s in spans
                               if s["name"] in ("grant", "claim", "run", "revoke")
                               and s.get("attempt", term) < term]
                    if earlier:
                        agg["retry_s"] += (max(s["end"] for s in earlier)
                                           - min(s["start"] for s in earlier))
                        agg["retries"] += len({s["attempt"] for s in earlier})
            if lo is not None and hi is not None:
                agg["wall_s"] = hi - lo
            stages[stage_name] = agg
        dominant = max(stages, key=lambda n: stages[n]["wall_s"]) if stages else None
        return {
            "campaign_id": campaign_id,
            "pipeline": st.pipeline,
            "state": st.state,
            "preemptions": st.preemptions,
            "wall_s": st.elapsed_s(),
            "stages": stages,
            "dominant_stage": dominant,
        }
