from . import knots

__all__ = ["knots"]
