"""Knot detection over predicted protein structures — the paper's workload
(§4), scaled to this container.

Pipeline mirrors AlphaKnot 2.0:

1. generate/ingest backbone traces (synthetic here: knotted families — the
   trefoil/figure-8 harmonic embeddings that Topoly uses as references — vs
   unknotted random coils; pLDDT-style quality filtering is emulated with a
   per-structure quality score),
2. **stage 1 screen**: total writhe + average crossing number (ACN) from the
   Gauss-linking writhe map (Pallas kernel / jnp ref) — the fast invariant,
   analogous to the paper's HOMFLY-PT screen with 200 random closures,
3. **stage 2 knot-core localization** for candidates passing the screen: the
   paper's subchain heuristic — slide (a, b) windows over the writhe map and
   find the smallest subchain whose |writhe| stays above threshold (the
   "knot core" that distinguishes deep from shallow knots).

Everything is batched (B, n_points, 3) and runs as KSA tasks in batches of
``batch_size`` structures (paper: 4000/task).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClusterComputing, register_script
from repro.kernels import ops as kops

WRITHE_KNOT_THRESHOLD = 2.5   # |Wr| above this ⇒ knot candidate
QUALITY_THRESHOLD = 0.70      # emulated pLDDT cut (paper: 70)


# ---------------------------------------------------------------------------
# synthetic structure generation
# ---------------------------------------------------------------------------

def torus_knot(p: int, q: int, n: int, scale: float = 1.0,
               noise: float = 0.0, seed: int = 0) -> np.ndarray:
    """(p, q) torus-knot backbone with n residues (3_1 = (2,3), 5_1 = (2,5))."""
    t = np.linspace(0, 2 * np.pi, n, endpoint=False)
    r = np.cos(q * t) + 2.0
    pts = np.stack([r * np.cos(p * t), r * np.sin(p * t),
                    -np.sin(q * t)], -1) * scale
    if noise:
        pts = pts + np.random.RandomState(seed).randn(n, 3) * noise
    return pts.astype(np.float32)


def figure8(n: int, noise: float = 0.0, seed: int = 0) -> np.ndarray:
    t = np.linspace(0, 2 * np.pi, n, endpoint=False)
    pts = np.stack([
        (2 + np.cos(2 * t)) * np.cos(3 * t),
        (2 + np.cos(2 * t)) * np.sin(3 * t),
        np.sin(4 * t)], -1)
    if noise:
        pts = pts + np.random.RandomState(seed).randn(n, 3) * noise
    return pts.astype(np.float32)


def random_coil(n: int, seed: int = 0,
                drift: tuple[float, float, float] = (1.0, 0.0, 0.0)
                ) -> np.ndarray:
    """Extended random coil: a drift term keeps the open chain from
    collapsing into a geometrically-entangled globule (unbiased walks often
    carry |Wr| > 3 — real, but noise for a screening benchmark)."""
    rng = np.random.RandomState(seed)
    steps = rng.randn(n, 3)
    steps /= np.linalg.norm(steps, axis=1, keepdims=True)
    steps = steps + np.asarray(drift)
    return np.cumsum(steps * 1.2, axis=0).astype(np.float32)


def deep_knot(n: int, core: int = 80, seed: int = 0) -> np.ndarray:
    """A trefoil core embedded mid-chain between two *extended* tails — the
    paper's 'deep knot' (Taylor 2000): trimming the tails keeps the knot.

    The torus-knot cut leaves both endpoints adjacent in space, so both tails
    must exit on the same side (radially outward) — ends that wander back
    through the loop would untie the open chain, which is exactly the
    shallow-knot failure mode the deep/shallow distinction is about."""
    tre = torus_knot(2, 3, core, scale=1.2, noise=0.03, seed=seed)
    center = tre.mean(0)
    d = tre[0] - center
    d = d / (np.linalg.norm(d) + 1e-9) * 5.0
    tail = (n - core) // 2
    head = random_coil(tail, seed + 1, drift=tuple(d)) + tre[0]
    foot = random_coil(n - core - tail, seed + 2, drift=tuple(d)) + tre[-1]
    return np.concatenate([head[::-1], tre, foot], 0).astype(np.float32)


def synthesize_batch(ids: list[int], n_points: int = 128) -> tuple[np.ndarray, list[str]]:
    """Deterministic mixed population keyed by structure id.

    Note: the figure-8 knot is amphichiral (Wr ≈ 0) and *invisible* to a
    writhe screen — exactly why the paper's pipeline computes HOMFLY-PT.
    The population here uses chiral knots (3_1, 5_1); the figure-8
    limitation is asserted explicitly in tests/test_knots.py."""
    out, truth = [], []
    for i in ids:
        kind = i % 4
        if kind == 0:
            out.append(torus_knot(2, 3, n_points, noise=0.05, seed=i))
            truth.append("trefoil")
        elif kind == 1:
            out.append(random_coil(n_points, seed=i))
            truth.append("unknot")
        elif kind == 2:
            out.append(torus_knot(2, 5, n_points, noise=0.05, seed=i))
            truth.append("cinquefoil")
        else:
            out.append(deep_knot(n_points, core=max(n_points // 2, 48),
                                 seed=i))
            truth.append("deep_trefoil")
    return np.stack(out), truth


def quality_score(ids: list[int]) -> np.ndarray:
    """Emulated pLDDT in [0.4, 1.0] (deterministic per id). ~15% of
    structures fall below the cut, mirroring the paper's 54M/214M drop."""
    rng = np.random.RandomState(12345)
    all_q = 0.4 + 0.6 * rng.random(10_000_000)
    return np.array([all_q[i % len(all_q)] for i in ids], np.float32)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------

def writhe_and_acn(coords: jax.Array, *, use_pallas: bool = False,
                   interpret: bool = False) -> tuple[jax.Array, jax.Array, jax.Array]:
    """-> (total writhe (B,), ACN (B,), writhe map (B, n, n))."""
    w = kops.writhe(coords, use_pallas=use_pallas, interpret=interpret)
    wr = w.sum(axis=(1, 2)) / 2.0
    acn = jnp.abs(w).sum(axis=(1, 2)) / 2.0
    return wr, acn, w


def knot_core(wmap: np.ndarray, threshold: float = WRITHE_KNOT_THRESHOLD,
              min_len: int = 16, check_cancel=None) -> tuple[int, int] | None:
    """Knot-core localization (paper §4: the subchain heuristic replacing
    the O(n²)-subchain Alexander knot map at AlphaFold scale).

    Shrinks [a, b) greedily from both ends while |writhe(subchain)| stays
    above threshold; O(n) evaluations over the precomputed map's prefix
    sums instead of O(n²) invariant computations. ``check_cancel`` is
    called once per shrink step — the O(chain-length) loop here is where a
    long localization actually spends its time, so a revoked lease must be
    observed *inside* it, not only between structures."""
    n = wmap.shape[0]
    # 2D prefix sums for O(1) subchain writhe
    ps = np.zeros((n + 1, n + 1))
    ps[1:, 1:] = np.cumsum(np.cumsum(wmap, 0), 1)

    def sub_writhe(a: int, b: int) -> float:
        return (ps[b, b] - ps[a, b] - ps[b, a] + ps[a, a]) / 2.0

    a, b = 0, n
    if abs(sub_writhe(a, b)) < threshold:
        return None
    changed = True
    while changed and b - a > min_len:
        if check_cancel is not None:
            check_cancel()
        changed = False
        if abs(sub_writhe(a + 1, b)) >= threshold:
            a += 1
            changed = True
        if b - a > min_len and abs(sub_writhe(a, b - 1)) >= threshold:
            b -= 1
            changed = True
    return (a, b)


def classify(wr: float) -> str:
    if abs(wr) < WRITHE_KNOT_THRESHOLD:
        return "unknot"
    return "knotted"


# ---------------------------------------------------------------------------
# the KSA task (paper Fig. 3 pattern)
# ---------------------------------------------------------------------------

def _screen_batch(ids: list[int], n_points: int, use_pallas: bool
                  ) -> tuple[list[int], list[int], dict[str, float], float]:
    """Quality-filter + writhe/ACN screen one batch of structure ids.
    -> (kept_ids, knotted_ids, writhe per knotted id, mean ACN over kept)."""
    q = quality_score(ids)
    keep = q >= QUALITY_THRESHOLD
    kept_ids = [i for i, k in zip(ids, keep) if k]
    if not kept_ids:
        return [], [], {}, 0.0
    coords, _ = synthesize_batch(kept_ids, n_points)
    wr, acn, _ = writhe_and_acn(jnp.asarray(coords), use_pallas=use_pallas,
                                interpret=use_pallas)
    wr = np.asarray(wr)
    knotted = [int(i) for i, w in zip(kept_ids, wr)
               if abs(float(w)) >= WRITHE_KNOT_THRESHOLD]
    wr_by_id = {str(i): float(w) for i, w in zip(kept_ids, wr)
                if int(i) in set(knotted)}
    return kept_ids, knotted, wr_by_id, float(np.asarray(acn).mean())


def _localize_cores(survivors: list[int], n_points: int, use_pallas: bool,
                    check_cancel) -> dict[str, list[int]]:
    """Knot-core localization for screen survivors. Shared by the flat
    ``knot_batch`` task and the pipeline ``knot_localize`` stage so the two
    paths cannot drift apart (flat-vs-campaign parity is asserted in tests
    and examples).

    ``check_cancel`` is required and called unconditionally in every
    O(chain-length) loop (here per structure, and inside each
    :func:`knot_core` shrink loop): a revoked lease
    (``Broker.revoke_lease`` — watchdog, preemption, drain, scancel) stops
    the task promptly instead of after the whole batch."""
    cores: dict[str, list[int]] = {}
    if not survivors:
        return cores
    coords, _ = synthesize_batch(survivors, n_points)
    _, _, wmap = writhe_and_acn(jnp.asarray(coords), use_pallas=use_pallas,
                                interpret=use_pallas)
    wmap_np = np.asarray(wmap)
    for k, i in enumerate(survivors):
        check_cancel()
        core = knot_core(wmap_np[k], check_cancel=check_cancel)
        if core is not None:
            cores[str(i)] = list(core)
    return cores


@register_script("knot_batch")
class KnotBatchComputing(ClusterComputing):
    """params: batch (list of structure ids), n_points, stage2 (bool),
    use_pallas. One task = one batch of structures (paper: 4000/batch).
    Flat single-stage baseline: screen + localize fused in one task, built
    on the same helpers the pipeline stages use."""

    def run(self) -> Any:
        ids = list(self.params["batch"])
        n_points = int(self.params.get("n_points", 128))
        stage2 = bool(self.params.get("stage2", True))
        use_pallas = bool(self.params.get("use_pallas", False))

        kept_ids, knotted, _, mean_acn = _screen_batch(ids, n_points,
                                                       use_pallas)
        self.send_status("RUNNING", stage="screen", kept=len(kept_ids),
                         dropped=len(ids) - len(kept_ids))
        self.check_cancel()

        cores: dict[str, list[int]] = {}
        if stage2 and knotted:
            self.send_status("RUNNING", stage="knot_core",
                             candidates=len(knotted))
            cores = _localize_cores(knotted, n_points, use_pallas,
                                    self.check_cancel)
        return {
            "processed": len(ids),
            "kept": len(kept_ids),
            "knotted": knotted,
            "cores": cores,
            "mean_acn": mean_acn,
        }


# ---------------------------------------------------------------------------
# the campaign as a 3-stage DAG (repro.pipeline)
# ---------------------------------------------------------------------------
#
# The same workload as ``knot_batch``, decomposed the way the paper's
# production deployment is (§4): a cheap screening stage fans out over
# batches, an expensive localization stage runs only on the survivors, and a
# join barrier aggregates the campaign. Stage results are numerically
# identical to the flat baseline because structure synthesis is deterministic
# per id.

@register_script("knot_screen")
class KnotScreenComputing(ClusterComputing):
    """Stage 1 (source, fan-out): generate + quality-filter + writhe/ACN
    screen one batch. params: batch (ids), n_points, use_pallas."""

    def run(self) -> Any:
        ids = list(self.params["batch"])
        n_points = int(self.params.get("n_points", 128))
        use_pallas = bool(self.params.get("use_pallas", False))
        kept_ids, knotted, wr_by_id, mean_acn = _screen_batch(
            ids, n_points, use_pallas)
        self.send_status("RUNNING", stage="screen", kept=len(kept_ids),
                         survivors=len(knotted))
        self.check_cancel()
        return {
            "processed": len(ids),
            "kept": len(kept_ids),
            "knotted": knotted,
            "wr": wr_by_id,
            "mean_acn": mean_acn,
        }


@register_script("knot_localize")
class KnotLocalizeComputing(ClusterComputing):
    """Stage 2 (map, 1:1 with screen tasks): knot-core localization on the
    survivors of one screen batch. The upstream screen result arrives as
    ``params["upstream"]``; coordinates are re-synthesized for survivors only
    (the paper ships structures via shared storage, not the broker)."""

    def run(self) -> Any:
        upstream = dict(self.params.get("upstream") or {})
        survivors = [int(i) for i in upstream.get("knotted", [])]
        n_points = int(self.params.get("n_points", 128))
        use_pallas = bool(self.params.get("use_pallas", False))
        cores = _localize_cores(survivors, n_points, use_pallas,
                                self.check_cancel)
        return {"candidates": len(survivors), "cores": cores}


@register_script("knot_aggregate")
class KnotAggregateComputing(ClusterComputing):
    """Stage 3 (join barrier): aggregate every screen + localize result into
    the campaign-level report. Fires exactly once per campaign."""

    def run(self) -> Any:
        upstream = dict(self.params.get("upstream") or {})
        screens = [r for r in upstream.get("screen", []) if r]
        locs = [r for r in upstream.get("localize", []) if r]
        processed = sum(int(r.get("processed", 0)) for r in screens)
        kept = sum(int(r.get("kept", 0)) for r in screens)
        knotted = sorted({int(i) for r in screens
                          for i in r.get("knotted", [])})
        cores: dict[str, list[int]] = {}
        for r in locs:
            cores.update(r.get("cores", {}))
        acn_num = sum(float(r.get("mean_acn", 0.0)) * int(r.get("kept", 0))
                      for r in screens)
        return {
            "processed": processed,
            "kept": kept,
            "knotted": knotted,
            "cores": cores,
            "mean_acn": acn_num / kept if kept else 0.0,
            "batches": len(screens),
        }


def _no_survivors(screen_result: Any) -> bool:
    """Conditional-edge predicate: a screen batch with no knot candidates has
    nothing to localize."""
    return not screen_result.get("knotted")


def knots_pipeline(batch_size: int = 12, *, n_points: int = 96,
                   use_pallas: bool = False,
                   max_in_flight: int | None = None,
                   max_attempts: int = 4,
                   task_timeout_s: float | None = None,
                   skip_empty: bool = True,
                   gpu_localize: bool = False,
                   localize_site: str = ""):
    """The AlphaKnot campaign as a declarative 3-stage DAG:
    screen (fan-out) → localize (map over survivors) → aggregate (join).

    Screen runs on cheap 1-CPU slots; localize requests more CPU (the
    heterogeneous-stage routing of ParaFold: different resource profiles per
    stage) — or, with ``gpu_localize``, a GPU: the writhe-map localization is
    the kernel-heavy stage, and requesting ``gpus=1`` routes it to the GPU
    class topic so only GPU pools (static or autoscaled) serve it, exactly
    ParaFold's CPU-featurize/GPU-predict split. Aggregate is a single
    barrier task. With ``skip_empty`` (default) localize tasks are *skipped*
    for screen batches with zero survivors — the ROADMAP's conditional-edge
    early exit; the campaign still completes, and the aggregate sees one
    result per non-empty batch.

    Under a :class:`~repro.federation.FederatedCluster`, ``localize_site``
    pins the kernel-heavy stage to a named federation site
    (``Resources.site`` affinity — e.g. the big remote HPC pool) while
    screen and aggregate stay site-free and run home or spill."""
    from repro.pipeline import PipelineSpec, RetryPolicy, Stage
    from repro.core import Resources

    retry = RetryPolicy(max_attempts=max_attempts, timeout_s=task_timeout_s)
    common = {"n_points": n_points, "use_pallas": use_pallas}
    localize_res = (Resources(cpus=1, gpus=1) if gpu_localize
                    else Resources(cpus=2))
    localize_res.site = localize_site
    return PipelineSpec("alphaknot", [
        Stage("screen", "knot_screen", fan_out=batch_size, params=common,
              resources=Resources(cpus=1), max_in_flight=max_in_flight,
              retry=retry),
        Stage("localize", "knot_localize", depends_on=("screen",),
              params=common, resources=localize_res,
              max_in_flight=max_in_flight, retry=retry,
              skip_when=_no_survivors if skip_empty else None),
        Stage("aggregate", "knot_aggregate",
              depends_on=("screen", "localize"), join=True, retry=retry),
    ])
