from .synthetic import SyntheticLMStream, batch_at

__all__ = ["SyntheticLMStream", "batch_at"]
