"""Deterministic, offset-addressable synthetic LM data.

``batch_at(seed, step, ...)`` is a pure function of (seed, step): the same
step index always produces the same batch, on any host. That is the property
that makes KSA step-chunk tasks idempotent — a redelivered chunk (agent
death, straggler resubmission) replays exactly the same data, so training is
bit-reproducible across failures — and it removes data-loader checkpointing
entirely (the data "checkpoint" is just the step counter).

The stream is a Markov-ish token process (not uniform noise) so smoke-scale
models actually have structure to learn; frontends get Gaussian embeddings
derived from the same counters.
"""
from __future__ import annotations

import numpy as np

from repro.models.config import ModelConfig


def batch_at(cfg: ModelConfig, seed: int, step: int, *, batch: int,
             seq: int) -> dict:
    """-> numpy batch dict for ``step`` (tokens/labels or embeds)."""
    rng = np.random.RandomState((seed * 1_000_003 + step) % (2 ** 31 - 1))
    v = cfg.vocab_size
    if cfg.frontend is not None and cfg.frontend.kind == "audio_frames":
        emb = rng.randn(batch, seq, cfg.frontend.input_dim).astype(np.float32)
        labels = rng.randint(0, v, (batch, seq)).astype(np.int32)
        return {"embeds": emb, "labels": labels}
    if cfg.frontend is not None and cfg.frontend.kind == "vit_patches":
        n_p = cfg.frontend.n_positions
        emb = rng.randn(batch, n_p, cfg.frontend.input_dim).astype(np.float32)
        tokens, labels = _lm_tokens(rng, batch, seq, v)
        return {"embeds": emb, "tokens": tokens, "labels": labels}
    tokens, labels = _lm_tokens(rng, batch, seq, v)
    return {"tokens": tokens, "labels": labels}


def _lm_tokens(rng: np.random.RandomState, batch: int, seq: int,
               vocab: int) -> tuple[np.ndarray, np.ndarray]:
    """Order-1 structured stream: next token depends on current (mod mixing),
    giving a learnable low-entropy component plus noise."""
    base = rng.randint(0, vocab, (batch, 1))
    steps = rng.randint(1, 17, (batch, seq))
    noise = (rng.random((batch, seq)) < 0.15) * rng.randint(
        0, vocab, (batch, seq))
    toks = (base + np.cumsum(steps, axis=1)) % vocab
    toks = np.where(noise > 0, noise, toks).astype(np.int32)
    labels = np.roll(toks, -1, axis=1).astype(np.int32)
    labels[:, -1] = toks[:, 0]
    return toks, labels


class SyntheticLMStream:
    """Iterator facade with explicit offset addressing (seek == set step)."""

    def __init__(self, cfg: ModelConfig, *, seed: int, batch: int, seq: int,
                 start_step: int = 0):
        self.cfg = cfg
        self.seed = seed
        self.batch = batch
        self.seq = seq
        self.step = start_step

    def seek(self, step: int) -> None:
        self.step = step

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = batch_at(self.cfg, self.seed, self.step, batch=self.batch,
                     seq=self.seq)
        self.step += 1
        return b
