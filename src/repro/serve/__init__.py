from .engine import (ServeEngine, ServePostprocessComputing,
                     ServeRequestComputing, ServeTokenizeComputing,
                     serve_pipeline)
from .metrics import register_serve_metrics
from .paged import PageAllocator
from .replica import (PendingRequest, ServeLoadGenComputing,
                      ServeReplicaComputing, ServeReplicaSet, ttft_slo)

__all__ = ["PageAllocator", "PendingRequest", "ServeEngine",
           "ServeLoadGenComputing", "ServePostprocessComputing",
           "ServeReplicaComputing", "ServeReplicaSet",
           "ServeRequestComputing", "ServeTokenizeComputing",
           "register_serve_metrics", "serve_pipeline", "ttft_slo"]
