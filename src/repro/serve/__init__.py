from .engine import (ServeEngine, ServePostprocessComputing,
                     ServeRequestComputing, ServeTokenizeComputing,
                     serve_pipeline)

__all__ = ["ServeEngine", "ServePostprocessComputing",
           "ServeRequestComputing", "ServeTokenizeComputing",
           "serve_pipeline"]
