from .engine import ServeEngine, ServeRequestComputing

__all__ = ["ServeEngine", "ServeRequestComputing"]
