"""Host-side page accounting for the paged KV cache.

The device side is a physical page pool per attention layer
(``init_paged_caches``) plus **one** page table shared by every paged layer
— slot positions advance uniformly across the stack, so the logical-page →
physical-page mapping is the same everywhere. This allocator owns that
table on the host (numpy; snapshotted to a device array once per engine
step) and a free-list of physical pages.

Admission cost is O(pages-touched): binding releases/claims a handful of
list entries and writes a few table cells — never a cache-tree rebuild.
Page 0 is reserved as the **trash page**: slots with no binding (inactive
lanes in the step's batch column) clamp their scatter writes to it, so the
jitted step needs no host round-trip to learn which lanes are live.
"""
from __future__ import annotations

import numpy as np

__all__ = ["PageAllocator"]


class PageAllocator:
    """Free-list allocator over ``n_pages`` physical pages for ``n_slots``
    request slots of up to ``pages_per_slot`` logical pages each.

    Not thread-safe on its own — the engine serializes access under its
    admission lock.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 pages_per_slot: int):
        if n_pages < 2:
            raise ValueError("need at least one usable page beyond the "
                             "reserved trash page 0")
        self.page_size = page_size
        self.n_pages = n_pages
        self.pages_per_slot = pages_per_slot
        self._free = list(range(n_pages - 1, 0, -1))  # page 0 reserved
        self.table = np.full((n_slots, pages_per_slot), -1, np.int32)

    # -- binding ----------------------------------------------------------

    def ensure(self, slot: int, position: int) -> bool:
        """Bind the page covering ``position`` for ``slot`` if it isn't
        already bound. Returns False when the pool is exhausted (the caller
        stalls or sheds the slot; nothing is modified)."""
        idx = position // self.page_size
        if idx >= self.pages_per_slot:
            return False  # past the table width: stall, never IndexError
        if self.table[slot, idx] >= 0:
            return True
        if not self._free:
            return False
        self.table[slot, idx] = self._free.pop()
        return True

    def release(self, slot: int) -> int:
        """Free every page bound to ``slot``; returns how many were freed."""
        row = self.table[slot]
        bound = row[row >= 0]
        self._free.extend(int(p) for p in bound)
        row[:] = -1
        return len(bound)

    # -- accounting -------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return int((self.table >= 0).sum())

    @property
    def capacity(self) -> int:
        """Usable pages (total minus the reserved trash page)."""
        return self.n_pages - 1

    def check(self) -> None:
        """Invariants: used + free == capacity, no page double-bound, no
        bound page on the free list, page 0 never handed out."""
        bound = self.table[self.table >= 0].tolist()
        assert len(bound) == len(set(bound)), "page double-bound"
        assert 0 not in bound, "trash page bound to a slot"
        assert 0 not in self._free, "trash page on the free list"
        assert not (set(bound) & set(self._free)), "bound page on free list"
        assert len(bound) + len(self._free) == self.capacity, \
            (len(bound), len(self._free), self.capacity)
