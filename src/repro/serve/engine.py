"""Serving with continuous batching, driven through the KSA broker.

This is the paper's AlphaKnot-2.0 deployment pattern (§4: "KSA is integrated
with the application's built-in web service … It manages all user requests
and performs the necessary computations behind the scenes") applied to LM
inference: requests arrive on ``PREFIX-new`` (script="serve_request"), a
serving agent owns the model and runs a **continuous-batching** loop —
slot-based KV caches, per-slot positions, join-on-arrival / leave-on-EOS —
and results flow back via ``PREFIX-done``.

The decode step is the same ``make_serve_step`` program the dry-run lowers;
per-slot positions use the per-batch ``q_offset`` path of chunked attention,
or the fused flash-decode kernel with ``decode_kernel="flash"``.

Admission is token-level and never blocks the device:

* the jitted step runs **outside** the engine lock — ``step()`` assembles a
  snapshot under the lock, dispatches, then applies results under the lock,
  skipping any slot whose generation counter moved (admitted/evicted
  mid-flight);
* admission does O(pages-touched) work, not an O(cache) tree rebuild:
  attention KV needs no zeroing at all (position masking — dense ``end``
  masks, ring-buffer negative positions, paged table clamps — already hides
  stale lanes) and only the recurrent leaves (ssd/rglru ``h``/``conv``
  state) of the admitted slot are zeroed, deferred to the next assembly;
* with ``paged=True`` the full-context KV lives in fixed-size pages bound
  on demand (``serve.paged.PageAllocator``), so admission binds one page
  and completion frees O(pages-used) — slots never reserve ``max_len``;
* a slot that loses the page race **stalls in place**: its table row is
  cleared for that step (the garbage lane's writes clamp to the trash
  page) and its per-slot lanes are rolled back afterwards, so it resumes
  bit-exact once pages free up.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClusterComputing, register_script
from repro.models.config import ModelConfig
from repro.models.transformer import (init_caches, init_paged_caches,
                                      paged_layout)
from repro.train.step import make_serve_step

from .paged import PageAllocator

_RECURRENT_KINDS = ("ssd", "rglru")
# positional caches are masked by k_valid/page-table logic; only recurrent
# state carries across steps unmasked and must be zeroed on admission.
_POSITIONAL_LEAVES = ("k", "v", "pool_k", "pool_v", "c_kv", "k_rope")


@dataclass
class _Slot:
    request_id: str | None = None
    tokens: list[int] = field(default_factory=list)
    prompt: list[int] = field(default_factory=list)
    max_new: int = 16
    position: int = 0
    done: bool = True
    gen: int = 0              # bumped on admit/evict; stale steps skip apply
    arrival_ts: float = 0.0
    got_first_token: bool = False
    base_prompt_len: int = 0  # original prompt length (resume replays the
                              # generated prefix as extra prompt tokens)


class ServeEngine:
    """Slot-based continuous batching around a single jitted decode step.

    All slots advance together each step (one ``serve_step`` call); finished
    slots are refilled from the queue without stalling the others — the
    property that keeps utilization high under ragged request lengths.

    ``step()`` must be driven by a single thread (the replica driver);
    ``add_request`` / ``evict`` may be called concurrently from any thread
    and only touch host state under the admission lock.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, n_slots: int = 4,
                 max_len: int = 512, eos_id: int | None = None,
                 paged: bool = False, page_size: int = 64,
                 n_pages: int | None = None,
                 decode_kernel: str | None = None,
                 kernel_interpret: bool | None = None,
                 admission: str = "lazy",
                 registry: Any = None, replica: str = "0",
                 step_latency_s: float = 0.0):
        if decode_kernel is not None:
            cfg = cfg.with_(decode_kernel=decode_kernel)
        if kernel_interpret is not None:
            cfg = cfg.with_(kernel_interpret=kernel_interpret)
        if admission not in ("lazy", "reset_full"):
            raise ValueError(f"unknown admission mode {admission!r}")
        if admission == "reset_full" and paged:
            # the full-lane zero indexes leaf dim 0 by slot, but paged
            # pool_k/pool_v lead with the *physical page* axis — zeroing
            # "slot i" there would wipe page i, which may hold another
            # request's KV. The legacy baseline is dense-cache only.
            raise ValueError("admission='reset_full' cannot be combined "
                             "with paged=True; use the default lazy "
                             "admission for paged caches")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.paged = paged
        self.admission = admission
        self.replica = replica
        self.step_latency_s = step_latency_s
        dt = jnp.dtype(cfg.dtype)
        if paged:
            pages_per_slot, pool_pages = paged_layout(max_len, page_size,
                                                      n_slots, n_pages)
            self.caches = init_paged_caches(cfg, n_slots, max_len, dt,
                                            page_size=page_size,
                                            n_pages=pool_pages)
            self.allocator: PageAllocator | None = PageAllocator(
                pool_pages, page_size, n_slots, pages_per_slot)
            self._serve = jax.jit(make_serve_step(cfg, paged=True))
        else:
            self.caches = init_caches(cfg, n_slots, max_len, dt)
            self.allocator = None
            self._serve = jax.jit(make_serve_step(cfg))
        self.slots = [_Slot() for _ in range(n_slots)]
        self._lock = threading.Lock()
        self._step_guard = threading.Lock()
        self._pending_reset: set[int] = set()
        self._has_recurrent = any(k in _RECURRENT_KINDS
                                  for k in cfg.layer_kinds())
        self._recent: deque = deque(maxlen=64)  # (ts, tokens) per step
        self.steps = 0
        self.tokens_out = 0
        self._m = None
        if registry is not None:
            from .metrics import register_serve_metrics
            fams = register_serve_metrics(registry)
            self._m = {name: fam.labels(replica=replica)
                       for name, fam in fams.items()
                       if name != "requests"}
            self._m_requests = fams["requests"]
            self._m["slots_total"].set(n_slots)
            if self.allocator is not None:
                self._m["pages_total"].set(self.allocator.capacity)

    def _event(self, event: str) -> None:
        if self._m is not None:
            self._m_requests.labels(replica=self.replica, event=event).inc()

    # -- request lifecycle ----------------------------------------------------

    def add_request(self, request_id: str, prompt: list[int],
                    max_new: int = 16, *, arrival_ts: float | None = None,
                    resume_tokens: list[int] | None = None) -> bool:
        """Claim a free slot; False if saturated or (paged) out of pages —
        the caller requeues. O(pages-touched): no device work beyond a
        deferred per-slot recurrent-state zero.

        ``resume_tokens`` re-admits an evicted request: the generated prefix
        is replayed as part of the prompt and greedy decoding continues
        deterministically from where it stopped.

        Raises ValueError for a request that can never fit: prompt feeding
        bypasses the max_len force-finish, so an oversized prompt would walk
        positions past the cache (and past the page table)."""
        total = len(prompt) + len(resume_tokens or [])
        if total >= self.max_len:
            raise ValueError(
                f"request {request_id!r} has {total} prompt tokens "
                f"(incl. resume) but max_len={self.max_len} leaves no "
                "decode position; it would never fit — truncate or raise "
                "max_len")
        now = time.time() if arrival_ts is None else arrival_ts
        with self._lock:
            for i, s in enumerate(self.slots):
                if not s.done:
                    continue
                if self.allocator is not None:
                    self.allocator.release(i)
                    if not self.allocator.ensure(i, 0):
                        return False  # page pool exhausted
                resumed = list(resume_tokens or [])
                self.slots[i] = _Slot(
                    request_id=request_id,
                    prompt=list(prompt) + resumed,
                    tokens=resumed, max_new=max_new,
                    position=0, done=False, gen=s.gen + 1,
                    arrival_ts=now,
                    got_first_token=bool(resumed),
                    base_prompt_len=len(prompt))
                if self.admission != "reset_full":
                    self._pending_reset.add(i)
                elif self._step_guard.locked():
                    # a step's device call may be in flight; its apply phase
                    # would clobber an eager zero with new_caches — defer to
                    # the next assembly, which runs under this lock.
                    self._pending_reset.add(i)
                else:
                    self._reset_slot_cache(i)
                if self._m is not None:
                    self._m["queue_wait"].observe(max(0.0, time.time() - now))
                self._event("admitted")
                return True
            return False

    def evict(self, request_id: str) -> dict | None:
        """Preempt a mid-generation request, freeing its slot (and pages)
        immediately. Returns the state needed to resume it elsewhere via
        ``add_request(..., resume_tokens=state["tokens"])``, or None if the
        request isn't active."""
        with self._lock:
            for i, s in enumerate(self.slots):
                if s.request_id == request_id and not s.done:
                    state = {"request_id": s.request_id,
                             "prompt": list(s.prompt[:s.base_prompt_len]),
                             "tokens": list(s.tokens),
                             "max_new": s.max_new}
                    s.done = True
                    s.gen += 1
                    if self.allocator is not None:
                        self.allocator.release(i)
                    self._event("evicted")
                    return state
            return None

    def _reset_slot_cache(self, i: int) -> None:
        """Legacy full-tree rebuild (admission="reset_full"): zeroes slot
        ``i``'s lane in *every* cache leaf — O(cache) device work per
        admission, kept as the benchmark baseline for the lazy path."""
        def zero_lane(path, c):
            keys = [getattr(p, "key", None) for p in path]
            bdim = 1 if "periods" in keys else 0  # stacked caches lead with L
            idx = [slice(None)] * c.ndim
            idx[bdim] = slice(i, i + 1)
            return c.at[tuple(idx)].set(0)
        self.caches = jax.tree_util.tree_map_with_path(zero_lane, self.caches)

    def _restore_lanes(self, new: Any, old: Any, idx: list[int]) -> Any:
        """Copy slot lanes ``idx`` of every per-slot cache leaf from ``old``
        (the pre-step snapshot) into ``new`` — used to undo the garbage-lane
        advance of slots that stalled on page-pool exhaustion. Physical page
        pools are skipped: their leading axis is the page, not the slot, and
        the cleared table rows already clamped those writes to the trash
        page."""
        rows = jnp.asarray(idx, jnp.int32)

        def restore(path, n, o):
            keys = [getattr(p, "key", None) for p in path]
            if keys[-1] in ("pool_k", "pool_v"):
                return n
            bdim = 1 if "periods" in keys else 0
            idx_t = (slice(None),) * bdim + (rows,)
            return n.at[idx_t].set(o[idx_t])
        return jax.tree_util.tree_map_with_path(restore, new, old)

    def _apply_resets(self) -> None:
        """Zero the state of newly admitted slots, batched across admissions
        since the last step: in lazy mode only the recurrent leaves
        (ssd/rglru h/conv — positional caches are left alone, masking
        already hides stale entries); in reset_full mode the full lane of
        any admission deferred because a step was in flight."""
        if not self._pending_reset:
            return
        idx = sorted(self._pending_reset)
        self._pending_reset.clear()
        if self.admission == "reset_full":
            for i in idx:
                self._reset_slot_cache(i)
            return
        if not self._has_recurrent:
            return
        rows = jnp.asarray(idx, jnp.int32)

        def zero_lane(path, c):
            keys = [getattr(p, "key", None) for p in path]
            if keys[-1] in _POSITIONAL_LEAVES:
                return c
            bdim = 1 if "periods" in keys else 0
            idx_t = (slice(None),) * bdim + (rows,)
            return c.at[idx_t].set(0)
        self.caches = jax.tree_util.tree_map_with_path(zero_lane, self.caches)

    def _active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.done]

    # -- the core loop step -----------------------------------------------------

    def step(self) -> list[tuple[str, list[int]]]:
        """Advance every active slot by one token (prompt-feeding slots
        consume their next prompt token; generating slots append). Returns
        finished (request_id, tokens) pairs.

        Three phases: assemble (lock), device call (no lock — admissions
        proceed concurrently), apply (lock, generation-checked)."""
        if not self._step_guard.acquire(blocking=False):
            raise RuntimeError("ServeEngine.step is single-driver; a step "
                               "is already in flight")
        try:
            return self._step()
        finally:
            self._step_guard.release()

    def _step(self) -> list[tuple[str, list[int]]]:
        with self._lock:
            active = self._active()
            if not active:
                return []
            self._apply_resets()
            col = np.zeros((self.n_slots, 1), np.int32)
            pos = np.zeros((self.n_slots,), np.int32)
            stepped: list[int] = []
            stalled: list[int] = []
            gens: dict[int, int] = {}
            for i in active:
                s = self.slots[i]
                if self.allocator is not None and \
                        not self.allocator.ensure(i, s.position):
                    stalled.append(i)
                    continue  # pool exhausted: slot stalls, retries next step
                if s.position < len(s.prompt):
                    col[i, 0] = s.prompt[s.position]
                else:
                    col[i, 0] = s.tokens[-1] if s.tokens else s.prompt[-1]
                pos[i] = s.position
                stepped.append(i)
                gens[i] = s.gen
            if not stepped:
                return []
            caches = self.caches
            pages = None
            if self.allocator is not None:
                table = self.allocator.table
                if stalled:
                    # a stalled slot still rides through the device call as a
                    # garbage lane (col=0, pos=0); clearing its row makes the
                    # K/V scatter clamp to the trash page instead of hitting
                    # its real, still-bound position-0 page.
                    table = table.copy()
                    table[stalled] = -1
                pages = jnp.asarray(table)

        t0 = time.time()
        if pages is not None:
            logits, next_ids, new_caches = self._serve(
                self.params, jnp.asarray(col), caches, jnp.asarray(pos),
                pages)
        else:
            logits, next_ids, new_caches = self._serve(
                self.params, jnp.asarray(col), caches, jnp.asarray(pos))
        next_ids = np.asarray(next_ids)  # device sync, still outside the lock
        if self.step_latency_s:
            # benchmark knob: emulate an accelerator-bound step on hosts
            # where the smoke model underruns real device latency.
            time.sleep(self.step_latency_s)
        dt = time.time() - t0

        with self._lock:
            if stalled:
                # the garbage lane also advanced per-slot state (recurrent
                # ssd/rglru h/conv, ring K/V at index 0) — roll those lanes
                # back to the pre-step snapshot so a stalled slot resumes
                # exactly where it paused.
                new_caches = self._restore_lanes(new_caches, caches, stalled)
            self.caches = new_caches
            self.steps += 1
            finished = []
            n_tokens = 0
            now = time.time()
            for i in stepped:
                s = self.slots[i]
                if s.done or s.gen != gens[i]:
                    continue  # evicted (and possibly re-filled) mid-flight
                s.position += 1
                if s.position < len(s.prompt):
                    continue  # still prefill-feeding
                tok = int(next_ids[i])
                s.tokens.append(tok)
                self.tokens_out += 1
                n_tokens += 1
                if not s.got_first_token:
                    s.got_first_token = True
                    if self._m is not None:
                        self._m["ttft"].observe(max(0.0, now - s.arrival_ts))
                if (len(s.tokens) >= s.max_new
                        or (self.eos_id is not None and tok == self.eos_id)
                        or s.position >= self.max_len - 1):
                    s.done = True
                    if self.allocator is not None:
                        self.allocator.release(i)
                    self._event("completed")
                    finished.append((s.request_id, list(s.tokens)))
            self._recent.append((now, n_tokens))
            if self._m is not None:
                self._m["step"].observe(dt)
                if n_tokens:
                    self._m["tokens"].inc(n_tokens)
                self._m["slots_active"].set(len(self._active()))
                if self.allocator is not None:
                    self._m["pages_used"].set(self.allocator.used_pages)
            return finished

    def throughput_tokens_s(self, window_s: float = 5.0) -> float:
        """Recent generation rate (host-side ring of per-step counts) —
        the router's fallback signal when the telemetry store is cold."""
        now = time.time()
        pts = [(t, n) for t, n in self._recent if t >= now - window_s]
        if len(pts) < 2:
            return 0.0
        span = pts[-1][0] - pts[0][0]
        return sum(n for _, n in pts) / max(span, 1e-6)

    def stats(self) -> dict:
        with self._lock:
            return {
                "replica": self.replica,
                "steps": self.steps,
                "tokens_out": self.tokens_out,
                "active_slots": len(self._active()),
                "n_slots": self.n_slots,
                "pages_used": (self.allocator.used_pages
                               if self.allocator else None),
                "pages_free": (self.allocator.free_pages
                               if self.allocator else None),
            }

    def run_until_drained(self, pending: list[tuple[str, list[int], int]],
                          max_steps: int = 10_000) -> dict[str, list[int]]:
        """Continuous batching over a request list: join-on-arrival."""
        results: dict[str, list[int]] = {}
        queue = deque(pending)  # popleft is O(1); list.pop(0) was O(n) per
        for _ in range(max_steps):  # admit, O(n²) over a long request log
            while queue and self.add_request(*queue[0]):
                queue.popleft()
            done = self.step()
            for rid, toks in done:
                results[rid] = toks
            if not queue and not self._active():
                break
        return results


@register_script("serve_request")
class ServeRequestComputing(ClusterComputing):
    """KSA task wrapper: one task = one generation request batch. Agents that
    own a ServeEngine process these; used by examples/serve_batch.py.

    Doubles as the *generate* stage of the serving pipeline: when run as a
    map stage, the tokenize stage's result arrives as ``params["upstream"]``
    and carries the request list."""

    engine: ServeEngine | None = None  # injected per-process

    def run(self) -> Any:
        if type(self).engine is None:
            raise RuntimeError("serving agent has no engine attached")
        requests = self.params.get("requests")
        if requests is None:
            requests = (self.params.get("upstream") or {}).get("requests", [])
        reqs = [(r["id"], list(r["prompt"]), int(r.get("max_new", 8)))
                for r in requests]
        t0 = time.time()
        results = type(self).engine.run_until_drained(reqs)
        dt = time.time() - t0
        return {"results": {k: v for k, v in results.items()},
                "tokens_per_s": sum(len(v) for v in results.values()) /
                                max(dt, 1e-9)}


# ---------------------------------------------------------------------------
# serving as a pipeline: tokenize → generate → post-process
# ---------------------------------------------------------------------------
#
# The same workload-agnostic DAG machinery that runs the knot campaign runs
# the serving path: raw texts fan out into tokenize batches (pure CPU), each
# tokenized batch maps 1:1 onto a generate task (the model-owning stage), and
# a join barrier assembles the response set. This is the AlphaKnot web-service
# pattern (§4) with the ParaFold-style CPU/accelerator stage split.

@register_script("serve_tokenize")
class ServeTokenizeComputing(ClusterComputing):
    """Pipeline stage 1 (source, fan-out): byte-level toy tokenizer.
    params: batch = [{"id", "text", "max_new"?}], vocab_size, max_new."""

    def run(self) -> Any:
        vocab = int(self.params.get("vocab_size", 256))
        default_max_new = int(self.params.get("max_new", 8))
        requests = []
        for r in self.params.get("batch", []):
            text = str(r.get("text", ""))
            prompt = [ord(c) % vocab for c in text] or [0]
            requests.append({"id": r["id"], "prompt": prompt,
                             "max_new": int(r.get("max_new",
                                                  default_max_new))})
        self.check_cancel()
        return {"requests": requests,
                "prompt_tokens": sum(len(r["prompt"]) for r in requests)}


@register_script("serve_postprocess")
class ServePostprocessComputing(ClusterComputing):
    """Pipeline stage 3 (join): merge every generate result into one
    response set with campaign-level throughput stats."""

    def run(self) -> Any:
        upstream = dict(self.params.get("upstream") or {})
        merged: dict[str, list[int]] = {}
        for r in upstream.get("generate", []):
            if r:
                merged.update(r.get("results", {}))
        self.check_cancel()
        return {
            "responses": {rid: {"tokens": toks, "n_tokens": len(toks)}
                          for rid, toks in sorted(merged.items())},
            "n_requests": len(merged),
            "total_tokens": sum(len(t) for t in merged.values()),
        }


def serve_pipeline(batch_size: int = 4, *, vocab_size: int = 256,
                   max_new: int = 8, max_in_flight: int | None = 1,
                   max_attempts: int = 3,
                   task_timeout_s: float | None = None):
    """Serving as a 3-stage DAG over raw-text items:
    tokenize (fan-out) → generate (map, model-owning pool) → post-process
    (join). ``max_in_flight`` defaults to 1 on generate so a single engine
    is never oversubscribed (backpressure at the stage level).

    The generate stage declares ``Resources(gpus=1)``, so under the default
    placement policy its tasks land on the ``-new.gpu`` class topic and only
    GPU-profiled (engine-owning) workers lease them, while tokenize and
    post-process drain on the CPU pool — the ParaFold split, wired through
    ``KsaCluster(gpu_workers=1, ...)`` or an explicit GPU ResourceProfile."""
    from repro.core import Resources
    from repro.pipeline import PipelineSpec, RetryPolicy, Stage

    retry = RetryPolicy(max_attempts=max_attempts, timeout_s=task_timeout_s)
    return PipelineSpec("serve", [
        Stage("tokenize", "serve_tokenize", fan_out=batch_size,
              params={"vocab_size": vocab_size, "max_new": max_new},
              resources=Resources(cpus=1), retry=retry),
        Stage("generate", "serve_request", depends_on=("tokenize",),
              resources=Resources(cpus=2, gpus=1, mem_mb=4096),
              max_in_flight=max_in_flight, retry=retry),
        Stage("postprocess", "serve_postprocess", depends_on=("generate",),
              join=True, retry=retry),
    ])
