"""Serving with continuous batching, driven through the KSA broker.

This is the paper's AlphaKnot-2.0 deployment pattern (§4: "KSA is integrated
with the application's built-in web service … It manages all user requests
and performs the necessary computations behind the scenes") applied to LM
inference: requests arrive on ``PREFIX-new`` (script="serve_request"), a
serving agent owns the model and runs a **continuous-batching** loop —
slot-based KV caches, per-slot positions, join-on-arrival / leave-on-EOS —
and results flow back via ``PREFIX-done``.

The decode step is the same ``make_serve_step`` program the dry-run lowers;
per-slot positions use the per-batch ``q_offset`` path of chunked attention.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClusterComputing, register_script
from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_caches
from repro.train.step import make_serve_step


@dataclass
class _Slot:
    request_id: str | None = None
    tokens: list[int] = field(default_factory=list)
    prompt: list[int] = field(default_factory=list)
    max_new: int = 16
    position: int = 0
    done: bool = True


class ServeEngine:
    """Slot-based continuous batching around a single jitted decode step.

    All slots advance together each step (one ``serve_step`` call); finished
    slots are refilled from the queue without stalling the others — the
    property that keeps utilization high under ragged request lengths.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, n_slots: int = 4,
                 max_len: int = 512, eos_id: int | None = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = init_caches(cfg, n_slots, max_len, jnp.dtype(cfg.dtype))
        self.slots = [_Slot() for _ in range(n_slots)]
        self._serve = jax.jit(make_serve_step(cfg))
        self._lock = threading.Lock()
        self.steps = 0
        self.tokens_out = 0

    # -- request lifecycle ----------------------------------------------------

    def add_request(self, request_id: str, prompt: list[int],
                    max_new: int = 16) -> bool:
        """Claim a free slot; False if saturated (caller requeues)."""
        with self._lock:
            for i, s in enumerate(self.slots):
                if s.done:
                    self.slots[i] = _Slot(request_id=request_id,
                                          prompt=list(prompt),
                                          tokens=[], max_new=max_new,
                                          position=0, done=False)
                    self._reset_slot_cache(i)
                    return True
            return False

    def _reset_slot_cache(self, i: int) -> None:
        def zero_lane(path, c):
            keys = [getattr(p, "key", None) for p in path]
            bdim = 1 if "periods" in keys else 0  # stacked caches lead with L
            idx = [slice(None)] * c.ndim
            idx[bdim] = slice(i, i + 1)
            return c.at[tuple(idx)].set(0)
        self.caches = jax.tree_util.tree_map_with_path(zero_lane, self.caches)

    def _active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.done]

    # -- the core loop step -----------------------------------------------------

    def step(self) -> list[tuple[str, list[int]]]:
        """Advance every active slot by one token (prompt-feeding slots
        consume their next prompt token; generating slots append). Returns
        finished (request_id, tokens) pairs."""
        with self._lock:
            active = self._active()
            if not active:
                return []
            # assemble the token column + per-slot positions
            col = np.zeros((self.n_slots, 1), np.int32)
            pos = np.zeros((self.n_slots,), np.int32)
            for i, s in enumerate(self.slots):
                if s.done:
                    continue
                if s.position < len(s.prompt):
                    col[i, 0] = s.prompt[s.position]
                else:
                    col[i, 0] = s.tokens[-1] if s.tokens else s.prompt[-1]
                pos[i] = s.position
            logits, next_ids, self.caches = self._serve(
                self.params, jnp.asarray(col), self.caches,
                jnp.asarray(pos))
            next_ids = np.asarray(next_ids)
            self.steps += 1
            finished = []
            for i, s in enumerate(self.slots):
                if s.done:
                    continue
                s.position += 1
                if s.position < len(s.prompt):
                    continue  # still prefill-feeding
                tok = int(next_ids[i])
                s.tokens.append(tok)
                self.tokens_out += 1
                if (len(s.tokens) >= s.max_new
                        or (self.eos_id is not None and tok == self.eos_id)
                        or s.position >= self.max_len - 1):
                    s.done = True
                    finished.append((s.request_id, list(s.tokens)))
            return finished

    def run_until_drained(self, pending: list[tuple[str, list[int], int]],
                          max_steps: int = 10_000) -> dict[str, list[int]]:
        """Continuous batching over a request list: join-on-arrival."""
        results: dict[str, list[int]] = {}
        queue = deque(pending)  # popleft is O(1); list.pop(0) was O(n) per
        for _ in range(max_steps):  # admit, O(n²) over a long request log
            while queue and self.add_request(*queue[0]):
                queue.popleft()
            done = self.step()
            for rid, toks in done:
                results[rid] = toks
            if not queue and not self._active():
                break
        return results


@register_script("serve_request")
class ServeRequestComputing(ClusterComputing):
    """KSA task wrapper: one task = one generation request batch. Agents that
    own a ServeEngine process these; used by examples/serve_batch.py.

    Doubles as the *generate* stage of the serving pipeline: when run as a
    map stage, the tokenize stage's result arrives as ``params["upstream"]``
    and carries the request list."""

    engine: ServeEngine | None = None  # injected per-process

    def run(self) -> Any:
        if type(self).engine is None:
            raise RuntimeError("serving agent has no engine attached")
        requests = self.params.get("requests")
        if requests is None:
            requests = (self.params.get("upstream") or {}).get("requests", [])
        reqs = [(r["id"], list(r["prompt"]), int(r.get("max_new", 8)))
                for r in requests]
        t0 = time.time()
        results = type(self).engine.run_until_drained(reqs)
        dt = time.time() - t0
        return {"results": {k: v for k, v in results.items()},
                "tokens_per_s": sum(len(v) for v in results.values()) /
                                max(dt, 1e-9)}


# ---------------------------------------------------------------------------
# serving as a pipeline: tokenize → generate → post-process
# ---------------------------------------------------------------------------
#
# The same workload-agnostic DAG machinery that runs the knot campaign runs
# the serving path: raw texts fan out into tokenize batches (pure CPU), each
# tokenized batch maps 1:1 onto a generate task (the model-owning stage), and
# a join barrier assembles the response set. This is the AlphaKnot web-service
# pattern (§4) with the ParaFold-style CPU/accelerator stage split.

@register_script("serve_tokenize")
class ServeTokenizeComputing(ClusterComputing):
    """Pipeline stage 1 (source, fan-out): byte-level toy tokenizer.
    params: batch = [{"id", "text", "max_new"?}], vocab_size, max_new."""

    def run(self) -> Any:
        vocab = int(self.params.get("vocab_size", 256))
        default_max_new = int(self.params.get("max_new", 8))
        requests = []
        for r in self.params.get("batch", []):
            text = str(r.get("text", ""))
            prompt = [ord(c) % vocab for c in text] or [0]
            requests.append({"id": r["id"], "prompt": prompt,
                             "max_new": int(r.get("max_new",
                                                  default_max_new))})
        self.check_cancel()
        return {"requests": requests,
                "prompt_tokens": sum(len(r["prompt"]) for r in requests)}


@register_script("serve_postprocess")
class ServePostprocessComputing(ClusterComputing):
    """Pipeline stage 3 (join): merge every generate result into one
    response set with campaign-level throughput stats."""

    def run(self) -> Any:
        upstream = dict(self.params.get("upstream") or {})
        merged: dict[str, list[int]] = {}
        for r in upstream.get("generate", []):
            if r:
                merged.update(r.get("results", {}))
        self.check_cancel()
        return {
            "responses": {rid: {"tokens": toks, "n_tokens": len(toks)}
                          for rid, toks in sorted(merged.items())},
            "n_requests": len(merged),
            "total_tokens": sum(len(t) for t in merged.values()),
        }


def serve_pipeline(batch_size: int = 4, *, vocab_size: int = 256,
                   max_new: int = 8, max_in_flight: int | None = 1,
                   max_attempts: int = 3,
                   task_timeout_s: float | None = None):
    """Serving as a 3-stage DAG over raw-text items:
    tokenize (fan-out) → generate (map, model-owning pool) → post-process
    (join). ``max_in_flight`` defaults to 1 on generate so a single engine
    is never oversubscribed (backpressure at the stage level).

    The generate stage declares ``Resources(gpus=1)``, so under the default
    placement policy its tasks land on the ``-new.gpu`` class topic and only
    GPU-profiled (engine-owning) workers lease them, while tokenize and
    post-process drain on the CPU pool — the ParaFold split, wired through
    ``KsaCluster(gpu_workers=1, ...)`` or an explicit GPU ResourceProfile."""
    from repro.core import Resources
    from repro.pipeline import PipelineSpec, RetryPolicy, Stage

    retry = RetryPolicy(max_attempts=max_attempts, timeout_s=task_timeout_s)
    return PipelineSpec("serve", [
        Stage("tokenize", "serve_tokenize", fan_out=batch_size,
              params={"vocab_size": vocab_size, "max_new": max_new},
              resources=Resources(cpus=1), retry=retry),
        Stage("generate", "serve_request", depends_on=("tokenize",),
              resources=Resources(cpus=2, gpus=1, mem_mb=4096),
              max_in_flight=max_in_flight, retry=retry),
        Stage("postprocess", "serve_postprocess", depends_on=("generate",),
              join=True, retry=retry),
    ])
