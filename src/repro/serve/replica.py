"""Replicated serving: N continuous-batching engines behind one router.

``ServeReplicaSet`` owns N :class:`~repro.serve.engine.ServeEngine` replicas,
each driven by its own loop (a local thread via :meth:`start`, or a
long-running KSA task on a ``serve``-tainted worker pool via :meth:`deploy`
— the pool is exclusive, so batch work never steals serving cycles and vice
versa). Requests enter through :meth:`submit`:

* **routing** — least projected queue wait, where the projection divides the
  replica's queued work (prompt + generation tokens ahead) by its recent
  token rate. The rate comes from the telemetry plane when available
  (``TimeSeriesStore.rate("ksa_serve_tokens_total", {"replica": ...})``)
  and falls back to the engine's host-side ring buffer while the store is
  cold;
* **SLO-aware admission** — when a TTFT :class:`~repro.obs.slo.SloSpec` is
  configured and even the best replica's projected wait exceeds the
  objective, the request is **shed** (rejected immediately, so the client
  can retry elsewhere) or **spilled** (handed to ``spill_to``, e.g. a
  federated remote site) instead of silently blowing the latency budget.

Admission into a slot is token-level (every driver iteration admits from
its queue before stepping), and the engines' lock discipline means a
client calling ``submit`` never blocks behind a jitted device call.

Request accounting is exact: every submitted request ends exactly one of
completed/shed/spilled, and double-resolution (a lost lease re-running a
generation) is counted in ``duplicates`` — the load-gen campaign asserts
both stay at zero lost / zero double-run.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core import ClusterComputing, Resources, register_script
from repro.core.scheduling import ResourceProfile

from .engine import ServeEngine

__all__ = ["PendingRequest", "ServeReplicaSet", "ServeReplicaComputing",
           "ServeLoadGenComputing", "ttft_slo"]


def ttft_slo(objective_s: float, q: float = 0.95):
    """A TTFT latency SLO for the serving tier: p``q`` of
    ``ksa_serve_ttft_seconds`` stays under ``objective_s``. Usable both for
    admission (:class:`ServeReplicaSet`) and alerting
    (:class:`~repro.obs.slo.AlertEngine`)."""
    from repro.obs.slo import SloSpec
    return SloSpec(name="serve-ttft", metric="ksa_serve_ttft_seconds",
                   objective=objective_s, kind="threshold", q=q)


@dataclass
class PendingRequest:
    """Client-side handle: resolves to the generated tokens (or a shed /
    spilled verdict) when the replica finishes."""
    request_id: str
    prompt: list[int]
    max_new: int
    arrival_ts: float
    status: str = "queued"      # queued | done | shed | spilled
    tokens: list[int] | None = None
    replica: int | None = None
    _event: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)

    @property
    def resolved(self) -> bool:
        return self._event.is_set()


class ServeReplicaSet:
    """N serving replicas, one router, exact request accounting."""

    def __init__(self, cfg, params, *, n_replicas: int = 2,
                 engine_kw: dict | None = None,
                 ttft_slo: Any = None, on_violation: str = "shed",
                 spill_to: Callable[[PendingRequest], None] | None = None,
                 registry: Any = None, store: Any = None,
                 rate_window_s: float = 10.0):
        if on_violation not in ("queue", "shed", "spill"):
            raise ValueError(f"unknown on_violation {on_violation!r}")
        kw = dict(engine_kw or {})
        self.engines = [ServeEngine(cfg, params, replica=f"r{i}",
                                    registry=registry, **kw)
                        for i in range(n_replicas)]
        self.n_replicas = n_replicas
        self.ttft_slo = ttft_slo
        self.on_violation = on_violation
        self.spill_to = spill_to
        self.store = store
        self.rate_window_s = rate_window_s
        self._queues: list[deque] = [deque() for _ in range(n_replicas)]
        self._pending: dict[str, PendingRequest] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._deployed: tuple | None = None
        self.submitted = 0
        self.completed = 0
        self.shed = 0
        self.spilled = 0
        self.duplicates = 0

    # -- routing / admission ----------------------------------------------

    def _rate_tokens_s(self, r: int) -> float:
        if self.store is not None:
            rate = self.store.rate("ksa_serve_tokens_total",
                                   {"replica": f"r{r}"}, self.rate_window_s)
            if rate > 0:
                return rate
        return self.engines[r].throughput_tokens_s()

    def projected_wait_s(self, r: int) -> float:
        """Estimated queue wait on replica ``r``: tokens of work already
        queued ahead, over the replica's recent token rate. 0 while the
        replica is cold (no rate signal yet — admit optimistically)."""
        with self._lock:
            queued = sum(len(p.prompt) + p.max_new for p in self._queues[r])
        if queued == 0:
            return 0.0
        rate = self._rate_tokens_s(r)
        if rate <= 0.0:
            return 0.0
        return queued / rate

    def submit(self, request_id: str, prompt: list[int],
               max_new: int = 16) -> PendingRequest:
        limit = min(e.max_len for e in self.engines)
        if len(prompt) >= limit:
            # reject in the client's thread: an unfittable request reaching
            # the driver loop would raise there and kill the replica.
            raise ValueError(
                f"request {request_id!r} prompt has {len(prompt)} tokens "
                f"but the replicas' max_len={limit} leaves no decode "
                "position")
        p = PendingRequest(request_id=request_id, prompt=list(prompt),
                           max_new=max_new, arrival_ts=time.time())
        waits = [self.projected_wait_s(r) for r in range(self.n_replicas)]
        best = min(range(self.n_replicas),
                   key=lambda r: (waits[r], len(self._queues[r])))
        with self._lock:
            if request_id in self._pending:
                raise ValueError(f"duplicate request id {request_id!r}")
            self.submitted += 1
            budget = (self.ttft_slo.objective
                      if self.ttft_slo is not None else None)
            if (budget is not None and waits[best] > budget
                    and self.on_violation != "queue"):
                if self.on_violation == "spill" and self.spill_to is not None:
                    p.status = "spilled"
                    self.spilled += 1
                    self.engines[best]._event("spilled")
                else:
                    p.status = "shed"
                    self.shed += 1
                    self.engines[best]._event("shed")
                self._pending[request_id] = p
                p._event.set()
            else:
                p.replica = best
                self._pending[request_id] = p
                self._queues[best].append(p)
        if p.status == "spilled":
            self.spill_to(p)
        return p

    # -- replica drivers ---------------------------------------------------

    def _drive_once(self, r: int) -> bool:
        """One driver iteration: admit from the queue, step, resolve.
        Returns True if there was any work."""
        eng = self.engines[r]
        q = self._queues[r]
        while True:
            with self._lock:
                if not q:
                    break
                head = q[0]
            if not eng.add_request(head.request_id, head.prompt,
                                   head.max_new,
                                   arrival_ts=head.arrival_ts):
                break
            with self._lock:
                if q and q[0] is head:
                    q.popleft()
        finished = eng.step()
        for rid, toks in finished:
            self._resolve(rid, toks)
        with self._lock:
            busy = bool(q) or bool(eng._active())
        return busy or bool(finished)

    def _resolve(self, rid: str, tokens: list[int]) -> None:
        with self._lock:
            p = self._pending.get(rid)
            if p is None:
                self.duplicates += 1
                return
            if p.resolved:
                self.duplicates += 1
                return
            p.tokens = tokens
            p.status = "done"
            self.completed += 1
            p._event.set()

    def _drive_loop(self, r: int,
                    check_cancel: Callable[[], None] | None = None) -> dict:
        while not self._stop.is_set():
            if check_cancel is not None:
                check_cancel()
            if not self._drive_once(r):
                time.sleep(0.002)
        return self.engines[r].stats()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "ServeReplicaSet":
        """Drive every replica with a local thread."""
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._drive_loop, args=(r,),
                             name=f"serve-replica-{r}", daemon=True)
            for r in range(self.n_replicas)]
        for t in self._threads:
            t.start()
        return self

    def deploy(self, cluster, *, taint: str = "serve") -> list[str]:
        """Run each replica driver as a long-lived KSA task on a
        ``taint``-tainted worker pool behind ``cluster``. The cluster must
        know the class: ``KsaCluster(placement=ResourceClassPolicy(
        extra_classes=("serve",)))``. One pool with ``n_replicas`` slots
        (not N single-slot pools: replica tasks are keyed records, and
        Kafka-style partition affinity can hash every driver onto one
        member's partitions — a saturated single-slot member would strand
        the rest forever). Returns the replica task ids (they complete when
        :meth:`stop` is called)."""
        ServeReplicaComputing.replica_set = self
        self._stop.clear()
        n = self.n_replicas
        cluster.add_worker(
            profile=ResourceProfile(cpus=n, mem_mb=1024 * n,
                                    labels=(taint,), taints=(taint,)),
            slots=n)
        ids = [cluster.submit("serve_replica", params={"replica": r},
                              resources=Resources(cpus=1, mem_mb=1024,
                                                  labels=(taint,)))
               for r in range(n)]
        self._deployed = (cluster, ids)
        return ids

    def stop(self, timeout: float = 30.0) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout)
        self._threads = []
        if self._deployed is not None:
            cluster, ids = self._deployed
            cluster.wait_all(ids, timeout=timeout)
            self._deployed = None

    def __enter__(self) -> "ServeReplicaSet":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # -- accounting --------------------------------------------------------

    def drain(self, timeout: float = 60.0) -> bool:
        """Wait until every submitted request has resolved."""
        deadline = time.time() + timeout
        with self._lock:
            pending = list(self._pending.values())
        for p in pending:
            if not p.wait(max(0.0, deadline - time.time())):
                return False
        return True

    @property
    def lost(self) -> int:
        """Requests unaccounted for (must be 0 after a clean drain)."""
        return self.submitted - self.completed - self.shed - self.spilled

    def describe(self) -> dict:
        return {
            "replicas": self.n_replicas,
            "submitted": self.submitted,
            "completed": self.completed,
            "shed": self.shed,
            "spilled": self.spilled,
            "duplicates": self.duplicates,
            "lost": self.lost,
            "engines": [e.stats() for e in self.engines],
        }


@register_script("serve_replica")
class ServeReplicaComputing(ClusterComputing):
    """One long-lived task = one replica driver, leased by a serve-tainted
    worker. The replica set is process-local state (the same injection
    pattern as ``ServeRequestComputing.engine``); the task pins the replica
    loop to the exclusive pool so the broker's lease/telemetry machinery
    sees the serving tier like any other workload."""

    replica_set: ServeReplicaSet | None = None  # injected by deploy()

    def run(self) -> Any:
        set_ = type(self).replica_set
        if set_ is None:
            raise RuntimeError("serve_replica task has no replica set "
                               "attached")
        r = int(self.params["replica"])
        return set_._drive_loop(r, check_cancel=self.check_cancel)


@register_script("serve_loadgen")
class ServeLoadGenComputing(ClusterComputing):
    """Load-generation client: submits ``n_requests`` deterministic prompts
    against the process-local replica set and waits for them all — run as a
    batch of concurrent tasks on the CPU pool, it is the campaign that
    drives the serving tier while the replicas run on their tainted pool.

    params: client (id), n_requests, prompt_len, max_new, vocab_size,
    inter_arrival_s."""

    replica_set: ServeReplicaSet | None = None  # injected per-process

    def run(self) -> Any:
        set_ = type(self).replica_set
        if set_ is None:
            raise RuntimeError("serve_loadgen task has no replica set "
                               "attached")
        client = str(self.params.get("client", "c0"))
        n = int(self.params.get("n_requests", 8))
        plen = int(self.params.get("prompt_len", 6))
        max_new = int(self.params.get("max_new", 8))
        vocab = int(self.params.get("vocab_size", 256))
        gap = float(self.params.get("inter_arrival_s", 0.0))
        timeout = float(self.params.get("timeout_s", 60.0))
        pending = []
        for j in range(n):
            prompt = [(17 * (j + 1) + 31 * k + len(client)) % vocab
                      for k in range(plen)]
            pending.append(set_.submit(f"{client}-{j}", prompt, max_new))
            if gap:
                time.sleep(gap)
            self.check_cancel()
        out = {"completed": 0, "shed": 0, "spilled": 0, "timed_out": 0,
               "tokens": 0}
        for p in pending:
            if not p.wait(timeout):
                out["timed_out"] += 1
                continue
            out[p.status if p.status != "done" else "completed"] += 1
            out["tokens"] += len(p.tokens or [])
        return out
