"""Pipeline benchmarks: campaign wall-clock and per-stage throughput of the
3-stage knots DAG vs the flat single-stage baseline on the same workload
(ISSUE satellite). The pipeline pays an orchestration hop per stage but only
runs knot-core localization on screen survivors — the ParaFold argument for
heterogeneous stage splits. All wiring goes through the KsaCluster facade."""
from __future__ import annotations

import time

from repro.apps import knots
from repro.cluster import KsaCluster


def bench_pipeline_vs_flat(n_structures: int = 96, batch_size: int = 16,
                           n_points: int = 96
                           ) -> list[tuple[str, float, str]]:
    rows = []
    ids = list(range(n_structures))

    # -- flat baseline: one bag of knot_batch tasks -------------------------
    with KsaCluster(prefix="bpf", poll_interval_s=0.005) as c:
        for _ in range(2):
            c.add_worker(slots=1)
        t0 = time.perf_counter()
        tids = c.submit_batches("knot_batch", ids, batch_size=batch_size,
                                params={"n_points": n_points,
                                        "stage2": True})
        ok = c.wait_all(tids, timeout=600.0)
        dt_flat = time.perf_counter() - t0
        flat_knotted = sorted({i for t in tids
                               for i in c.result(t)["knotted"]})

    rows.append(("campaign_flat", dt_flat / n_structures * 1e6,
                 f"{'ok' if ok else 'FAIL'}: {n_structures} structures in "
                 f"{dt_flat:.1f} s ({n_structures/dt_flat:.1f}/s), "
                 f"{len(flat_knotted)} knotted"))

    # -- 3-stage DAG campaign through the facade ----------------------------
    with KsaCluster(prefix="bpp", poll_interval_s=0.005) as c:
        for _ in range(2):
            c.add_worker(slots=1)
        spec = knots.knots_pipeline(batch_size, n_points=n_points)
        t0 = time.perf_counter()
        res = c.run_campaign(spec, ids, timeout_s=600.0)
        dt_pipe = time.perf_counter() - t0
    match = res.final["knotted"] == flat_knotted
    rows.append(("campaign_pipeline_3stage", dt_pipe / n_structures * 1e6,
                 f"{n_structures} structures in {dt_pipe:.1f} s "
                 f"({n_structures/dt_pipe:.1f}/s), "
                 f"{len(res.final['knotted'])} knotted "
                 f"({'parity' if match else 'MISMATCH'}), "
                 f"overhead {dt_pipe/dt_flat:.2f}x flat"))
    for name, ss in res.status.stages.items():
        per_task = res.elapsed_s / max(ss.done, 1)
        rows.append((f"campaign_stage_{name}", per_task * 1e6,
                     f"{ss.done}/{ss.expected} tasks, "
                     f"{ss.retried} retried, {ss.duplicates} dup-fenced, "
                     f"{ss.skipped} skipped"))
    return rows


def bench_pipeline_orchestration_overhead(n_tasks: int = 64
                                          ) -> list[tuple[str, float, str]]:
    """Pure control-plane cost: a fan-out→join DAG of no-op sleep tasks vs
    the same tasks submitted flat — isolates the PipelineAgent's per-task
    orchestration hop (result ingest + downstream emit)."""
    from repro.pipeline import PipelineSpec, Stage

    with KsaCluster(prefix="bpo", poll_interval_s=0.002) as c:
        c.add_worker(slots=4)
        t0 = time.perf_counter()
        tids = [c.submit("sleep", params={"duration": 0.0})
                for _ in range(n_tasks)]
        c.wait_all(tids, timeout=120.0)
        dt_flat = time.perf_counter() - t0

        spec = PipelineSpec("noop", [
            Stage("a", "sleep", fan_out=1, params={"duration": 0.0}),
            Stage("b", "sleep", depends_on=("a",), params={"duration": 0.0}),
        ])
        t0 = time.perf_counter()
        c.run_campaign(spec, list(range(n_tasks // 2)), timeout_s=120.0)
        dt_pipe = time.perf_counter() - t0
    return [
        ("orchestration_flat", dt_flat / n_tasks * 1e6,
         f"{n_tasks} no-op tasks in {dt_flat*1e3:.0f} ms"),
        ("orchestration_pipeline", dt_pipe / n_tasks * 1e6,
         f"{n_tasks} no-op tasks (2-stage chain) in {dt_pipe*1e3:.0f} ms, "
         f"{dt_pipe/dt_flat:.2f}x flat"),
    ]
