"""Control-plane benchmarks — one per performance factor the paper names in
§6, plus the §2/§7 Celery-comparison claim quantified on SimSlurm."""
from __future__ import annotations

import queue
import threading
import time

from repro.cluster import KsaCluster
from repro.core import Broker, Consumer, Producer, SimSlurm


def bench_broker_throughput(n_msgs: int = 20_000) -> list[tuple[str, float, str]]:
    """§6: throughput vs topic partition count."""
    rows = []
    for parts in (1, 4, 16):
        b = Broker()
        b.create_topic("t", partitions=parts)
        p = Producer(b)
        t0 = time.perf_counter()
        for i in range(n_msgs):
            p.send("t", {"i": i}, key=str(i))
        t_prod = time.perf_counter() - t0
        c = Consumer(b, ["t"], group_id="g")
        t0 = time.perf_counter()
        seen = 0
        while seen < n_msgs:
            for recs in c.poll(0.1).values():
                seen += len(recs)
        t_cons = time.perf_counter() - t0
        b.close()
        rows.append((f"broker_produce_p{parts}", t_prod / n_msgs * 1e6,
                     f"{n_msgs / t_prod:,.0f} msg/s"))
        rows.append((f"broker_consume_p{parts}", t_cons / n_msgs * 1e6,
                     f"{n_msgs / t_cons:,.0f} msg/s"))
    return rows


def bench_submit_latency() -> list[tuple[str, float, str]]:
    """§6: submission -> execution delay vs agent polling interval."""
    rows = []
    for poll_s in (0.001, 0.02, 0.1):
        with KsaCluster(prefix="lat", poll_interval_s=0.001) as c:
            c.add_worker(slots=2, poll_interval_s=poll_s)
            lats = []
            for _ in range(20):
                t0 = time.perf_counter()
                tid = c.submit("sleep", params={"duration": 0.0})
                c.wait_all([tid], timeout=10.0, poll=0.0005)
                lats.append(time.perf_counter() - t0)
        lats.sort()
        med = lats[len(lats) // 2]
        rows.append((f"submit_latency_poll{int(poll_s*1000)}ms",
                     med * 1e6, f"median e2e {med*1e3:.1f} ms"))
    return rows


class _CeleryStyleWorkerPool:
    """The paper's §2 anti-pattern: long-running workers squat on cluster
    slots for the whole campaign, pulling tasks from an internal queue."""

    def __init__(self, slurm: SimSlurm, n_slots: int):
        self.slurm = slurm
        self.q: queue.Queue = queue.Queue()
        self.done = 0
        self._stop = threading.Event()
        self.job_ids = [
            slurm.sbatch(self._worker, name=f"celery-worker-{i}", cpus=1,
                         user="celery")
            for i in range(n_slots)
        ]

    def _worker(self, cancel_event=None) -> None:
        while not self._stop.is_set():
            try:
                dur = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            time.sleep(dur)
            self.done += 1

    def submit(self, duration: float) -> None:
        self.q.put(duration)

    def shutdown(self) -> None:
        self._stop.set()


def bench_oversubscription_vs_celery(n_tasks: int = 60,
                                     task_s: float = 0.05
                                     ) -> list[tuple[str, float, str]]:
    """Quantifies §2/§7: while a campaign runs, how long does an *external
    user's* job wait? KSA releases slots between tasks; Celery-style workers
    hog them until the pool is torn down."""
    rows = []

    # --- KSA ClusterAgent path ---
    slurm = SimSlurm(nodes=2, cpus_per_node=2)
    ext_wait = {}

    def ext_job(cancel_event=None):
        ext_wait["run"] = time.perf_counter()

    with KsaCluster(prefix="ov", poll_interval_s=0.005) as c:
        c.add_slurm(slurm, oversubscribe=4)
        ids = [c.submit("sleep", params={"duration": task_s}, cpus=1)
               for _ in range(n_tasks)]
        time.sleep(task_s * 4)
        t_sub = time.perf_counter()
        slurm.sbatch(ext_job, name="external-user", cpus=1,
                     user="someone_else")
        c.wait_all(ids, timeout=120.0)
        t_all = time.perf_counter() - t_sub
        wait_ksa = ext_wait["run"] - t_sub
    slurm.shutdown()
    rows.append(("external_wait_ksa", wait_ksa * 1e6,
                 f"external user waited {wait_ksa*1e3:.0f} ms"))
    rows.append(("campaign_ksa", t_all * 1e6,
                 f"campaign {t_all:.2f} s, util model: slots released"))

    # --- Celery-style long-running pool ---
    slurm = SimSlurm(nodes=2, cpus_per_node=2)
    pool = _CeleryStyleWorkerPool(slurm, n_slots=4)
    for _ in range(n_tasks):
        pool.submit(task_s)
    time.sleep(task_s * 4)
    ext_wait2 = {}

    def ext_job2(cancel_event=None):
        ext_wait2["run"] = time.perf_counter()

    t_sub = time.perf_counter()
    slurm.sbatch(ext_job2, name="external-user", cpus=1, user="someone_else")
    while pool.done < n_tasks:
        time.sleep(0.005)
    t_all2 = time.perf_counter() - t_sub
    pool.shutdown()
    slurm.wait_all(timeout=30.0)
    wait_celery = ext_wait2.get("run", time.perf_counter()) - t_sub
    slurm.shutdown()
    rows.append(("external_wait_celery", wait_celery * 1e6,
                 f"external user waited {wait_celery*1e3:.0f} ms "
                 f"(vs {wait_ksa*1e3:.0f} ms under KSA)"))
    rows.append(("campaign_celery", t_all2 * 1e6,
                 f"campaign {t_all2:.2f} s, slots held for the whole run"))
    return rows


def bench_startup_sync() -> list[tuple[str, float, str]]:
    """§6: agent/monitor startup vs number of retained task statuses."""
    rows = []
    for n in (1_000, 10_000, 50_000):
        b = Broker()
        p = Producer(b)
        for i in range(n):
            p.send("st-jobs", {"task_id": f"t{i}", "status": "DONE",
                               "attempt": 0}, key=f"t{i}")
        t0 = time.perf_counter()
        with KsaCluster(prefix="st", broker=b,
                        poll_interval_s=0.001) as c:
            while c.monitor.summary()["tasks"] < n:
                time.sleep(0.002)
            dt = time.perf_counter() - t0
        b.close()
        rows.append((f"monitor_startup_{n}_statuses", dt / n * 1e6,
                     f"{dt:.2f} s to sync {n} statuses"))
    return rows


def bench_failure_recovery() -> list[tuple[str, float, str]]:
    """Watchdog redelivery latency: agent dies mid-task -> replacement
    completes; reports the added makespan."""
    with KsaCluster(prefix="fr", session_timeout_s=0.5, task_timeout_s=0.4,
                    poll_interval_s=0.005,
                    agent_kw=dict(heartbeat_interval_s=0.1)) as c:
        a1 = c.add_worker(slots=1)
        t0 = time.perf_counter()
        tid = c.submit("sleep", params={"duration": 0.2})
        time.sleep(0.05)
        a1.crash()
        c.add_worker(slots=1)
        ok = c.wait_all([tid], timeout=30.0)
        dt = time.perf_counter() - t0
    return [("failure_recovery_e2e", dt * 1e6,
             f"{'ok' if ok else 'FAILED'}: 0.2s task survived agent kill "
             f"in {dt:.2f} s")]
