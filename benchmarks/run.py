"""Benchmark driver — one benchmark per paper table/figure/§6 factor.
Prints ``name,us_per_call,derived`` CSV. Roofline tables (the LM perf
report) are produced separately by ``python -m benchmarks.roofline`` from
the dry-run artifacts."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_apps, bench_autoscale, bench_broker, bench_core,
                   bench_federation, bench_obs, bench_pipeline,
                   bench_preemption, bench_recovery, bench_routing,
                   bench_serve)

    suites = [
        ("broker_data_plane", bench_broker.bench_broker_data_plane),
        ("broker_throughput", bench_core.bench_broker_throughput),
        ("submit_latency", bench_core.bench_submit_latency),
        ("oversubscription_vs_celery",
         bench_core.bench_oversubscription_vs_celery),
        ("startup_sync", bench_core.bench_startup_sync),
        ("failure_recovery", bench_core.bench_failure_recovery),
        ("resource_routing", bench_routing.bench_resource_routing),
        ("fair_share", bench_routing.bench_fair_share),
        ("writhe_kernel", bench_apps.bench_writhe_kernel),
        ("knot_campaign", bench_apps.bench_knot_campaign),
        ("pipeline_vs_flat", bench_pipeline.bench_pipeline_vs_flat),
        ("pipeline_orchestration_overhead",
         bench_pipeline.bench_pipeline_orchestration_overhead),
        ("journal_overhead", bench_recovery.bench_journal_overhead),
        ("recovery_time", bench_recovery.bench_recovery_time),
        ("autoscale_burst", bench_autoscale.bench_autoscale_burst),
        ("federation", bench_federation.bench_federation),
        ("preemption", bench_preemption.bench_preemption),
        ("obs_overhead", bench_obs.bench_obs_overhead),
        ("train_step", bench_apps.bench_train_step),
        ("serve_continuous_batching",
         bench_apps.bench_serve_continuous_batching),
        ("serve_tier", bench_serve.bench_serve),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.2f},\"{derived}\"", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},NaN,\"ERROR\"", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
