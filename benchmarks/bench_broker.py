"""Broker data-plane benchmark (ISSUE 8 acceptance: >= 3x grant->commit).

PR 8 sharded the broker's single master lock into per-partition, per-group
and per-lease-shard locks and vectorized the grant hot path (batched lease
grants, ``observe_many``/``add_batch`` obs flushes, cached ``topic_class``
and histogram label children). ``Broker(single_lock=True)`` preserves the
seed's serialized data plane — per-record grants, value copies, uncached
class parses and per-record label/observe/span work under one master
RLock — as the honest baseline.

Method: queue N self-describing task records, then drain them with K agent
threads each looping ``lease_records(64) -> claim_start -> complete_lease``
(the full grant->commit lease lifecycle). Throughput is committed tasks per
second of drain wall time; latency is the per-``lease_records``-call wall
time, reported at p50/p99. Acceptance: at 100k queued, sharded throughput
with 4 agent threads must be >= 3x single-lock, and sharded p99 lease
latency no worse (1.25x tolerance for timer noise). The p99 comparison
uses the 1-thread cell: on a single-core GIL runtime, wall-time p99 of a
concurrent design at N threads measures scheduler preemption (other
threads' GIL slices landing inside the timed call), which a fully
serialized baseline dodges by keeping every other thread blocked — the
uncontended cell is the apples-to-apples latency. The 1M-depth cells cap
the drain at ``DRAIN_CAP`` tasks (logged in the JSON) so the matrix stays
under a couple of minutes; depth beyond the cap does not change per-task
cost — the queues are O(1) at both ends.

Results land in ``BENCH_broker.json`` next to the repo root so the perf
trajectory of the data plane is tracked across PRs.
"""
from __future__ import annotations

import gc
import json
import os
import threading
import time

from repro.core.broker import Broker, Consumer

LEASE_BATCH = 64
DRAIN_CAP = 120_000  # max tasks actually drained per cell (1M cells)
ACCEPT_DEPTH = 100_000
ACCEPT_THREADS = 4
ACCEPT_SPEEDUP = 3.0
P99_TOLERANCE = 1.25

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_broker.json")


def _fill(broker: Broker, n: int) -> None:
    produce = broker.produce
    for i in range(n):
        tid = f"t{i}"
        produce("bb-new.cpu", {"task_id": tid, "payload": i}, key=tid)


def _drain(broker: Broker, n_threads: int, budget: int) -> dict:
    """Drain up to ``budget`` tasks with ``n_threads`` lease->claim->commit
    agent loops; returns throughput + lease-call latency percentiles."""
    counts = [0] * n_threads
    lats: list[list[float]] = [[] for _ in range(n_threads)]
    errors: list = []
    total = [0]
    total_lock = threading.Lock()  # bumped once per wave, not per task

    def agent(idx: int) -> None:
        try:
            c = Consumer(broker, ["bb-new.cpu"], group_id="g")
            my_lats = lats[idx]
            while total[0] < budget:  # racy read: stop signal only
                t0 = time.perf_counter()
                recs = broker.lease_records("g", c.member_id,
                                            max_records=LEASE_BATCH)
                my_lats.append(time.perf_counter() - t0)
                if not recs:
                    break
                ev = threading.Event()
                wave = [(r.value["task_id"], r.value.get("attempt", 0))
                        for r in recs]
                broker.claim_start_batch(wave, c.member_id, ev)
                commits = broker.complete_lease_batch(wave, c.member_id)
                n_ok = sum(1 for v in commits.values() if v)
                counts[idx] += n_ok
                with total_lock:
                    total[0] += n_ok
                    if total[0] >= budget:
                        break
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=agent, args=(i,))
               for i in range(n_threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    completed = sum(counts)
    all_lats = sorted(x for ls in lats for x in ls)

    def pct(p: float) -> float:
        if not all_lats:
            return 0.0
        return all_lats[min(len(all_lats) - 1, int(p * len(all_lats)))]

    return {"completed": completed, "wall_s": wall,
            "tasks_per_s": completed / max(wall, 1e-9),
            "lease_calls": len(all_lats),
            "lease_p50_us": pct(0.50) * 1e6,
            "lease_p99_us": pct(0.99) * 1e6}


def _cell(mode: str, n_threads: int, depth: int, repeats: int = 1) -> dict:
    """One benchmark cell, best-of-``repeats`` runs (scheduler noise on a
    shared box only ever *subtracts* throughput, so max is the honest
    estimate — same policy as bench_obs)."""
    best: dict | None = None
    for _ in range(max(1, repeats)):
        gc.collect()
        broker = Broker(default_partitions=8,
                        single_lock=(mode == "single"),
                        session_timeout_s=1e9)
        broker.create_topic("bb-new.cpu", partitions=8)
        _fill(broker, depth)
        budget = min(depth, DRAIN_CAP)
        res = _drain(broker, n_threads, budget)
        broker.close()
        if best is None or res["tasks_per_s"] > best["tasks_per_s"]:
            best = res
    best.update({"mode": mode, "threads": n_threads, "depth": depth,
                 "drain_cap": min(depth, DRAIN_CAP), "repeats": repeats})
    return best


def bench_broker_data_plane() -> list[tuple[str, float, str]]:
    cells = []
    matrix = [(t, d, 3) for d in (10_000, 100_000) for t in (1, 4)]
    matrix += [(4, 1_000_000, 1)]
    for mode in ("single", "sharded"):
        for n_threads, depth, repeats in matrix:
            cells.append(_cell(mode, n_threads, depth, repeats))

    def find(mode: str, threads: int, depth: int) -> dict:
        return next(c for c in cells if c["mode"] == mode
                    and c["threads"] == threads and c["depth"] == depth)

    base = find("single", ACCEPT_THREADS, ACCEPT_DEPTH)
    fast = find("sharded", ACCEPT_THREADS, ACCEPT_DEPTH)
    speedup = fast["tasks_per_s"] / max(base["tasks_per_s"], 1e-9)
    # p99 is compared on the 1-thread cell: with N CPU-bound threads on a
    # single-core GIL runtime, wall-time p99 of any *concurrent* design
    # measures scheduler preemption (other threads' 5ms GIL slices land
    # inside the timed call), which the serialized baseline dodges by
    # keeping every other thread blocked on the master lock. Uncontended
    # latency is the apples-to-apples number; the 4-thread wall p99s stay
    # in the JSON for transparency.
    base_1t = find("single", 1, ACCEPT_DEPTH)
    fast_1t = find("sharded", 1, ACCEPT_DEPTH)
    p99_ratio = fast_1t["lease_p99_us"] / max(base_1t["lease_p99_us"], 1e-9)
    accepted = speedup >= ACCEPT_SPEEDUP and p99_ratio <= P99_TOLERANCE
    payload = {
        "bench": "broker_data_plane",
        "lease_batch": LEASE_BATCH,
        "drain_cap": DRAIN_CAP,
        "cells": cells,
        "acceptance": {
            "throughput_cell": {"threads": ACCEPT_THREADS,
                                "depth": ACCEPT_DEPTH},
            "speedup_vs_single_lock": speedup,
            "required_speedup": ACCEPT_SPEEDUP,
            "latency_cell": {"threads": 1, "depth": ACCEPT_DEPTH},
            "p99_ratio_vs_single_lock": p99_ratio,
            "p99_tolerance": P99_TOLERANCE,
            "accepted": accepted,
        },
    }
    with open(_JSON_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    assert accepted, (
        f"broker data plane acceptance failed: speedup {speedup:.2f}x "
        f"(need >= {ACCEPT_SPEEDUP}x), p99 ratio {p99_ratio:.2f} "
        f"(need <= {P99_TOLERANCE})")
    rows = []
    for c in cells:
        rows.append((
            f"broker_{c['mode']}_{c['threads']}t_{c['depth']//1000}k",
            1e6 / max(c["tasks_per_s"], 1e-9),
            f"{c['tasks_per_s']:.0f} tasks/s "
            f"p99={c['lease_p99_us']:.0f}us",
        ))
    rows.append(("broker_sharded_speedup_4t_100k",
                 0.0, f"{speedup:.2f}x vs single-lock "
                      f"(p99 ratio {p99_ratio:.2f})"))
    return rows
