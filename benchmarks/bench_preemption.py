"""Preemptive fair share benchmark (ISSUE: lease-lifecycle tentpole).

The over-share scenario FairShare alone cannot fix: a big campaign of long
tasks is submitted first and its leases occupy every pool slot; a small,
heavier-weight campaign arrives moments later. Submission-time arbitration
(weighted round-robin at grant time) only helps once a slot frees *on its
own* — the small campaign's tail latency is bounded below by the big
campaign's task duration. Preemptive fair share
(``FairShare(preempt_factor=...)`` + ``RetryPolicy(max_preemptions=...)``)
revokes the over-share campaign's longest-running leases through
``Broker.revoke_lease(reason="preempt")`` — cancel, commit fence, journaled
``LeaseRevoked``, regrant through the pump — so the starved campaign runs
immediately and the preempted work is requeued, not lost.

Reported per config (submission-time-only vs preemptive): the small
campaign's **tail latency** (time from its submission to its completion),
the big campaign's makespan (the price paid for preempting), preemption
count, and the loss/duplication audit. Acceptance bar (asserted here and
in tests/test_lease.py): preemptive tail latency ≥ 2x better than
submission-time-only FairShare, with zero lost and zero double-run tasks.

A ``BENCH_preemption.json`` summary is written next to the repo root so
the perf trajectory tracks preemption across PRs.
"""
from __future__ import annotations

import json
import os
import time

from repro.cluster import KsaCluster
from repro.core import FairShare
from repro.pipeline import PipelineSpec, RetryPolicy, Stage

BIG_TASKS = 8
BIG_TASK_S = 1.0
SMALL_TASKS = 2
SMALL_TASK_S = 0.05
HEAD_START_S = 0.3
SLOTS = 2

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_preemption.json")


def _spec(name: str, n_tasks_duration: float, *,
          max_preemptions: int = 0) -> PipelineSpec:
    return PipelineSpec(name, [
        Stage("work", "sleep", fan_out=1,
              params={"duration": n_tasks_duration},
              retry=RetryPolicy(max_attempts=3, timeout_s=60.0,
                                max_preemptions=max_preemptions)),
    ])


def _run_config(name: str, *, preemptive: bool) -> dict:
    big = _spec(f"big-{name}", BIG_TASK_S,
                max_preemptions=BIG_TASKS if preemptive else 0)
    small = _spec(f"small-{name}", SMALL_TASK_S)
    lease = FairShare(preempt_factor=1.5) if preemptive else FairShare()
    with KsaCluster(prefix=f"pre-{name}", workers=1, worker_slots=SLOTS,
                    poll_interval_s=0.005, lease=lease,
                    max_in_flight_total=SLOTS) as c:
        t0 = time.perf_counter()
        bid = c.submit_campaign(big, list(range(BIG_TASKS)), weight=1.0)
        time.sleep(HEAD_START_S)
        t_small = time.perf_counter()
        sid = c.submit_campaign(small, list(range(SMALL_TASKS)), weight=4.0)
        st_small = c.wait_campaign(sid, timeout=120.0)
        tail_s = time.perf_counter() - t_small
        st_big = c.wait_campaign(bid, timeout=300.0)
        big_makespan_s = time.perf_counter() - t0
        assert st_small.state == "COMPLETED" and st_big.state == "COMPLETED"
        done = sum(s.done for st in (st_big, st_small)
                   for s in st.stages.values())
        expect = sum(s.expected for st in (st_big, st_small)
                     for s in st.stages.values())
        dups = sum(s.duplicates for st in (st_big, st_small)
                   for s in st.stages.values())
        return {
            "small_tail_s": round(tail_s, 3),
            "big_makespan_s": round(big_makespan_s, 3),
            "preemptions": st_big.preemptions,
            "revoked": {k: v for k, v in
                        c.status()["leases"]["revoked"].items() if v},
            "tasks_done": done,
            "tasks_expected": expect,
            "lost": expect - done,
            "duplicates_fenced": dups,
        }


def bench_preemption() -> list[tuple[str, float, str]]:
    baseline = _run_config("base", preemptive=False)
    preempt = _run_config("pe", preemptive=True)

    speedup = baseline["small_tail_s"] / max(preempt["small_tail_s"], 1e-9)
    # the acceptance contract: >= 2x tail improvement, nothing lost, nothing
    # double-run — in either configuration
    assert speedup >= 2.0, (baseline, preempt)
    for cfg in (baseline, preempt):
        assert cfg["lost"] == 0 and cfg["duplicates_fenced"] == 0, cfg
    assert preempt["preemptions"] >= 1

    payload = {
        "over_share_tail_latency": {
            "big_tasks": BIG_TASKS, "big_task_s": BIG_TASK_S,
            "small_tasks": SMALL_TASKS, "small_task_s": SMALL_TASK_S,
            "slots": SLOTS, "head_start_s": HEAD_START_S,
            "submission_time_only": baseline,
            "preemptive": preempt,
            "tail_speedup": round(speedup, 2),
            "zero_loss": baseline["lost"] == 0 and preempt["lost"] == 0,
            "zero_duplicates": (baseline["duplicates_fenced"] == 0
                                and preempt["duplicates_fenced"] == 0),
        },
    }
    with open(_JSON_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)

    return [
        ("preemption_baseline_tail", baseline["small_tail_s"] * 1e6,
         f"submission-time-only FairShare: starved campaign tail "
         f"{baseline['small_tail_s']:.2f} s (blocked behind "
         f"{BIG_TASK_S:.1f}s leases)"),
        ("preemption_preemptive_tail", preempt["small_tail_s"] * 1e6,
         f"preemptive FairShare: tail {preempt['small_tail_s']:.2f} s "
         f"({speedup:.1f}x vs submission-time-only; target >= 2x), "
         f"{preempt['preemptions']} preemptions, "
         f"lost={preempt['lost']} dups={preempt['duplicates_fenced']}"),
        ("preemption_big_makespan", preempt["big_makespan_s"] * 1e6,
         f"preempted campaign makespan {preempt['big_makespan_s']:.2f} s "
         f"vs {baseline['big_makespan_s']:.2f} s unpreempted — the requeue "
         f"cost of giving slots back"),
    ]
