"""Durability benchmarks for the event-sourced pipeline (ISSUE satellite):

* ``bench_journal_overhead`` — the same no-op DAG campaign with the
  write-ahead journal on (default) vs off (``pipeline_journal=False``, the
  pre-refactor in-memory baseline): what appending every campaign event to
  ``PREFIX-campaigns`` costs per task.
* ``bench_recovery_time`` — ``KsaCluster.recover()`` wall time vs campaign
  size: a synthetic mid-flight journal (every task dispatched+leased, half
  done) is folded, repaired, and resubmitted by a fresh orchestrator.
"""
from __future__ import annotations

import dataclasses
import time

from repro.cluster import KsaCluster
from repro.core.broker import Producer
from repro.core.messages import topic_names
from repro.pipeline import (CampaignSubmitted, LeaseGranted, PipelineSpec,
                            Stage, StageDispatched, TaskDone)


def _noop_spec() -> PipelineSpec:
    return PipelineSpec("noop", [
        Stage("a", "sleep", fan_out=1, params={"duration": 0.0}),
        Stage("b", "sleep", depends_on=("a",), params={"duration": 0.0}),
    ])


def bench_journal_overhead(n_items: int = 32
                           ) -> list[tuple[str, float, str]]:
    rows = []
    timings = {}
    for journal in (False, True):
        prefix = "bjo1" if journal else "bjo0"
        with KsaCluster(prefix=prefix, poll_interval_s=0.002,
                        pipeline_journal=journal) as c:
            c.add_worker(slots=4)
            t0 = time.perf_counter()
            c.run_campaign(_noop_spec(), list(range(n_items)),
                           timeout_s=120.0)
            timings[journal] = time.perf_counter() - t0
            events = c.pipeline.stats()["events_journaled"]
        n_tasks = 2 * n_items
        label = "journaled" if journal else "in_memory_baseline"
        extra = (f"{events} events appended"
                 if journal else "no WAL (not crash-recoverable)")
        rows.append((f"campaign_{label}", timings[journal] / n_tasks * 1e6,
                     f"{n_tasks} tasks in {timings[journal]*1e3:.0f} ms, "
                     f"{extra}"))
    rows.append(("journal_overhead_ratio",
                 (timings[True] - timings[False]) / (2 * n_items) * 1e6,
                 f"journal adds {timings[True]/max(timings[False], 1e-9):.2f}x"
                 f" wall vs in-memory baseline"))
    return rows


def _mid_flight_journal(prefix: str, cid: str, n_tasks: int) -> list:
    """A dead orchestrator's journal: n source tasks planned and leased,
    half of them done — the shape recover() folds after a crash."""
    events = [CampaignSubmitted(campaign_id=cid, pipeline="wide",
                                items=tuple(range(n_tasks)), params={},
                                weight=1.0)]
    for i in range(n_tasks):
        tid = f"{cid}-work-{i:05d}"
        events.append(StageDispatched(campaign_id=cid, stage="work",
                                      task_id=tid, index=i,
                                      params={"batch": [i],
                                              "batch_index": i}))
        events.append(LeaseGranted(campaign_id=cid, task_id=tid, attempt=0))
        if i < n_tasks // 2:
            events.append(TaskDone(campaign_id=cid, task_id=tid,
                                   result={"i": i}))
    return [dataclasses.replace(ev, seq=s, ts=time.time())
            for s, ev in enumerate(events)]


def bench_recovery_time(sizes: tuple[int, ...] = (16, 64, 256)
                        ) -> list[tuple[str, float, str]]:
    rows = []
    for n in sizes:
        spec = PipelineSpec("wide", [
            Stage("work", "sleep", fan_out=1, params={"duration": 0.0}),
        ])
        prefix = f"brt{n}"
        with KsaCluster(prefix=prefix, monitor=False,
                        poll_interval_s=0.005) as c:
            prod = Producer(c.broker)
            topic = topic_names(prefix)["campaigns"]
            cid = f"camp-bench-{n}"
            events = _mid_flight_journal(prefix, cid, n)
            for ev in events:
                prod.send(topic, ev.to_dict(), key=cid)
            t0 = time.perf_counter()
            recovered = c.recover([spec])
            dt = time.perf_counter() - t0
            st = c.campaign_status(cid)
        rows.append((f"recovery_{n}_tasks", dt / n * 1e6,
                     f"{'ok' if recovered == [cid] else 'FAIL'}: folded "
                     f"{len(events)} events, resubmitted "
                     f"{st.stages['work'].retried} in-flight tasks in "
                     f"{dt*1e3:.1f} ms"))
    return rows
