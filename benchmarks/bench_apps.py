"""Application benchmarks: the paper's knot-scan campaign (§4) and the
LM substrate (train step / continuous-batching serving)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import knots
from repro.cluster import KsaCluster
from repro.configs import smoke_config
from repro.kernels import ref as kref
from repro.kernels.writhe import writhe_map
from repro.models import init_params, model_spec
from repro.optim import OptimizerConfig
from repro.serve import ServeEngine
from repro.train import init_train_state, make_train_step


def bench_writhe_kernel(batch: int = 8, n_points: int = 257
                        ) -> list[tuple[str, float, str]]:
    """§4 workload: writhe-map throughput, jnp ref vs Pallas (interpret).
    (Real-TPU numbers come from the roofline: the kernel's O(n²/block²) VMEM
    tiling; interpret mode only proves correctness-at-speed parity.)"""
    rng = np.random.RandomState(0)
    coords = jnp.asarray(np.cumsum(rng.randn(batch, n_points, 3), 1),
                         jnp.float32)
    f_ref = jax.jit(kref.writhe_map_ref)
    f_ref(coords).block_until_ready()
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        f_ref(coords).block_until_ready()
    dt_ref = (time.perf_counter() - t0) / reps
    n_pairs = batch * (n_points - 1) ** 2
    rows = [("writhe_ref_jit", dt_ref / batch * 1e6,
             f"{n_pairs / dt_ref / 1e6:.1f} Mpairs/s, "
             f"{batch / dt_ref:.1f} structures/s")]
    out = writhe_map(coords, block=64, interpret=True)
    err = float(jnp.abs(out - f_ref(coords)).max())
    rows.append(("writhe_pallas_interpret_maxerr", err * 1e6,
                 f"max |Δ| vs ref = {err:.1e}"))
    return rows


def bench_knot_campaign(n_structures: int = 96, batch_size: int = 16
                        ) -> list[tuple[str, float, str]]:
    """Mini AlphaKnot campaign (paper: 160M structures / batches of 4000 /
    3 clusters): here scaled down, 2 agents, makespan + throughput."""
    with KsaCluster(prefix="kc", poll_interval_s=0.005) as c:
        for _ in range(2):
            c.add_worker(slots=1)
        ids = list(range(n_structures))
        t0 = time.perf_counter()
        tids = c.submit_batches("knot_batch", ids, batch_size=batch_size,
                                params={"n_points": 96, "stage2": True})
        ok = c.wait_all(tids, timeout=600.0)
        dt = time.perf_counter() - t0
        knotted = sum(len(c.result(t)["knotted"]) for t in tids)
    return [("knot_campaign", dt / n_structures * 1e6,
             f"{'ok' if ok else 'FAIL'}: {n_structures} structures "
             f"in {dt:.1f} s ({n_structures/dt:.1f}/s), {knotted} knotted")]


def bench_train_step() -> list[tuple[str, float, str]]:
    cfg = smoke_config("stablelm_1_6b")
    ocfg = OptimizerConfig(warmup_steps=0, schedule="constant")
    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, ocfg))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 64)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 64)),
                                   jnp.int32)}
    state, _ = step(state, batch)  # compile
    jax.block_until_ready(state.params)
    t0 = time.perf_counter()
    reps = 10
    for _ in range(reps):
        state, m = step(state, batch)
    jax.block_until_ready(state.params)
    dt = (time.perf_counter() - t0) / reps
    toks = 8 * 64
    return [("train_step_smoke", dt * 1e6,
             f"{toks/dt:,.0f} tok/s (CPU, smoke config)")]


def bench_serve_continuous_batching() -> list[tuple[str, float, str]]:
    cfg = smoke_config("stablelm_1_6b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [(f"r{i}", list(rng.randint(0, cfg.vocab_size, 4 + i % 5)), 8)
            for i in range(12)]
    t0 = time.perf_counter()
    out = eng.run_until_drained(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    return [("serve_continuous_batching", dt / max(toks, 1) * 1e6,
             f"{toks} tokens in {dt:.1f} s = {toks/dt:.1f} tok/s "
             f"(CPU smoke, {eng.steps} engine steps)")]
