"""Routing & fairness benchmarks (ISSUE satellite).

1. ``bench_resource_routing`` — a mixed CPU/GPU workload on a heterogeneous
   pool: with the paper's flat shared topic every agent leases every task, so
   GPU work queues behind the CPU backlog (and can land on nodes that, on
   real hardware, could not run it at all); with resource-aware routing the
   GPU class topic feeds the GPU pool directly. Reports the GPU batch's
   completion latency and any misplaced executions under each policy.

2. ``bench_fair_share`` — two concurrent campaigns on one worker: under FIFO
   leasing the late small campaign drains only after the big one (tail
   latency ≈ the whole makespan); under FairShare weighted round-robin it
   interleaves proportionally.
"""
from __future__ import annotations

import time

from repro.cluster import KsaCluster
from repro.core import (FairShare, FifoLease, ResourceClassPolicy,
                        ResourceProfile, SingleTopicPolicy)
from repro.pipeline import PipelineSpec, RetryPolicy, Stage


def _mixed_run(placement, routed: bool, n_cpu: int, n_gpu: int,
               task_s: float) -> tuple[float, float, int]:
    """-> (gpu batch latency, total makespan, gpu tasks run off-pool)."""
    with KsaCluster(prefix="rt", placement=placement,
                    poll_interval_s=0.002) as c:
        for _ in range(2):
            c.add_worker(slots=1, profile=None if not routed
                         else ResourceProfile(cpus=1))
        gpu_agent = c.add_worker(
            slots=1, profile=None if not routed
            else ResourceProfile(cpus=1, gpus=1))
        t0 = time.perf_counter()
        cpu_ids = [c.submit("sleep", params={"duration": task_s}, cpus=1)
                   for _ in range(n_cpu)]
        gpu_ids = [c.submit("sleep", params={"duration": task_s}, gpus=1)
                   for _ in range(n_gpu)]
        assert c.wait_all(gpu_ids, timeout=120.0)
        dt_gpu = time.perf_counter() - t0
        assert c.wait_all(cpu_ids, timeout=120.0)
        dt_all = time.perf_counter() - t0
        misplaced = sum(1 for t in gpu_ids
                        if c.task(t).agent_id != gpu_agent.agent_id)
    return dt_gpu, dt_all, misplaced


def bench_resource_routing(n_cpu: int = 40, n_gpu: int = 4,
                           task_s: float = 0.05
                           ) -> list[tuple[str, float, str]]:
    flat_gpu, flat_all, flat_misplaced = _mixed_run(
        SingleTopicPolicy(), False, n_cpu, n_gpu, task_s)
    # dedicated GPU pool (gpu_takes_cpu=False): the ParaFold split — the GPU
    # stage never waits behind CPU work the pool happened to lease.
    routed_gpu, routed_all, routed_misplaced = _mixed_run(
        ResourceClassPolicy(gpu_takes_cpu=False), True, n_cpu, n_gpu, task_s)
    return [
        ("routing_flat_gpu_latency", flat_gpu * 1e6,
         f"{n_gpu} GPU tasks done after {flat_gpu*1e3:.0f} ms behind a "
         f"{n_cpu}-task CPU backlog; {flat_misplaced} ran off the GPU pool"),
        ("routing_classed_gpu_latency", routed_gpu * 1e6,
         f"{n_gpu} GPU tasks done after {routed_gpu*1e3:.0f} ms "
         f"({flat_gpu/max(routed_gpu, 1e-9):.1f}x faster than flat); "
         f"{routed_misplaced} misplaced (must be 0)"),
        ("routing_flat_makespan", flat_all * 1e6,
         f"mixed campaign {flat_all:.2f} s on the shared topic"),
        ("routing_classed_makespan", routed_all * 1e6,
         f"mixed campaign {routed_all:.2f} s with cpu/gpu class topics"),
    ]


def bench_fair_share(n_big: int = 24, n_small: int = 6, task_s: float = 0.02
                     ) -> list[tuple[str, float, str]]:
    rows = []
    # FIFO baseline = the pre-lease behaviour: no backpressure bound, every
    # task hits the topic at submit time and drains first-come. FairShare
    # keeps ready queues (max_in_flight) and interleaves them by weight.
    for name, lease, bound in (("fifo", FifoLease(), None),
                               ("fair_share", FairShare(), 2)):
        spec = PipelineSpec("fs", [
            Stage("work", "sleep", fan_out=1, params={"duration": task_s},
                  max_in_flight=bound, retry=RetryPolicy(max_attempts=2)),
        ])
        with KsaCluster(prefix=f"fs{name[:2]}", lease=lease,
                        poll_interval_s=0.002) as c:
            c.add_worker(slots=1)
            t0 = time.perf_counter()
            big = c.submit_campaign(spec, list(range(n_big)), weight=1.0)
            small = c.submit_campaign(spec, list(range(n_small)), weight=1.0)
            c.wait_campaign(small, timeout=120.0)
            dt_small = time.perf_counter() - t0
            c.wait_campaign(big, timeout=120.0)
            dt_all = time.perf_counter() - t0
        rows.append((f"fairshare_{name}_small_tail", dt_small * 1e6,
                     f"{n_small}-task campaign (behind a {n_big}-task peer) "
                     f"finished at {dt_small*1e3:.0f} ms of a "
                     f"{dt_all*1e3:.0f} ms makespan under {name}"))
    return rows
