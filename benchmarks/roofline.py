"""Roofline report generator: reads ``results/dryrun/*.json`` (produced by
``repro.launch.dryrun``) and emits the §Roofline markdown table + per-cell
sentences. Usage: ``PYTHONPATH=src python -m benchmarks.roofline
[--dir results/dryrun] [--mesh pod16x16]``."""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

MOVE_HINTS = {
    "memory": ("fuse the attention/logit blocks (Pallas flash kernel / "
               "chunked CE) so logits and S×S scores never round-trip HBM"),
    "collective": ("reduce TP psum traffic: reduce-scatter + sequence-"
                   "sharded residuals, or shrink the TP degree for this "
                   "arch"),
    "compute": ("shrink redundant FLOPs: remat policy (recompute ratio), "
                "causal block skipping, smaller capacity factor"),
}


def load(dir_: str, mesh: str) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(f"{dir_}/*__{mesh}.json")):
        d = json.loads(Path(f).read_text())
        if d.get("ok") and "roofline" in d:
            rows.append(d)
    return rows


def fmt_table(rows: list[dict]) -> str:
    out = ["| arch | shape | step | compute s | memory s (floor) | "
           "collective s | dominant | useful FLOPs | MFU bound | fits 16GB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        r = d["roofline"]
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['step']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"({r.get('memory_floor_s', 0):.4f}) "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {min(r['useful_flops_ratio'], 9.99):.3f} "
            f"| {r['mfu_bound']:.3f} "
            f"| {'yes' if d['memory']['fits_16gb'] else 'NO'} |")
    return "\n".join(out)


def fmt_sentences(rows: list[dict]) -> str:
    out = []
    for d in rows:
        r = d["roofline"]
        out.append(
            f"- **{d['arch']} × {d['shape']}**: dominated by "
            f"{r['dominant']} ({r['step_time_bound_s']:.3f}s bound; "
            f"MODEL_FLOPS {r['model_flops_total']:.3e}, "
            f"useful-FLOPs ratio {r['useful_flops_ratio']:.3f}); to move it: "
            f"{MOVE_HINTS[r['dominant']]}.")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--sentences", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    print(f"### Roofline — {args.mesh} ({len(rows)} cells)\n")
    print(fmt_table(rows))
    if args.sentences:
        print()
        print(fmt_sentences(rows))


if __name__ == "__main__":
    main()
