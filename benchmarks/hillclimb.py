"""§Perf hillclimbing driver: compiles tagged optimization variants of the
three chosen cells and prints before/after roofline terms.

Cells (selection rationale in EXPERIMENTS.md §Perf):
  1. deepseek-v3-671b × decode_32k   — most collective-bound cell
  2. deepseek-v3-671b × train_4k     — worst roofline fraction / HBM violator
  3. moonshot-v1-16b-a3b × train_4k  — most representative of the EP (expert-
                                        parallel) substrate of this system

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [--cell N]
"""
import sys

from repro.launch.dryrun import run_cell  # sets XLA_FLAGS first

from pathlib import Path

OUT = Path("results/dryrun")

VARIANTS = {
    "deepseek_decode": [
        ("deepseek_v3_671b", "decode_32k", {}, ""),
        ("deepseek_v3_671b", "decode_32k",
         {"dist_flags": ["flash_decode"]}, "flashdec"),
        ("deepseek_v3_671b", "decode_32k",
         {"dist_flags": ["flash_decode", "weight_stationary"]}, "flashdec_ws"),
    ],
    "deepseek_train": [
        ("deepseek_v3_671b", "train_4k", {}, ""),
        ("deepseek_v3_671b", "train_4k",
         {"dist_flags": ["fp8_gather"]}, "fp8"),
        ("deepseek_v3_671b", "train_4k",
         {"dist_flags": ["fp8_gather", "chunked_ce"]}, "fp8_cce"),
        ("deepseek_v3_671b", "train_4k",
         {"dist_flags": ["fp8_gather", "chunked_ce"], "microbatch": 8},
         "fp8_cce_mu8"),
        ("deepseek_v3_671b", "train_4k",
         {"dist_flags": ["fp8_gather", "chunked_ce"],
          "score_dtype": "bfloat16"}, "fp8_cce_bf16s"),
    ],
    "moonshot_train": [
        ("moonshot_v1_16b_a3b", "train_4k", {}, ""),
        ("moonshot_v1_16b_a3b", "train_4k",
         {"dist_flags": ["chunked_ce"]}, "cce"),
        ("moonshot_v1_16b_a3b", "train_4k",
         {"dist_flags": ["chunked_ce", "fp8_gather"]}, "cce_fp8"),
        ("moonshot_v1_16b_a3b", "train_4k",
         {"dist_flags": ["chunked_ce", "fp8_gather"], "microbatch": 4},
         "cce_fp8_mu4"),
        ("moonshot_v1_16b_a3b", "train_4k",
         {"dist_flags": ["chunked_ce", "fp8_gather"],
          "score_dtype": "bfloat16"}, "cce_fp8_bf16s"),
    ],
}


def main() -> None:
    which = sys.argv[1:] or list(VARIANTS)
    for group in which:
        print(f"\n=== {group} ===", flush=True)
        for arch, shape, overrides, tag in VARIANTS[group]:
            rec = run_cell(arch, shape, False, OUT, overrides=overrides,
                           tag=tag)
            r = rec.get("roofline", {})
            m = rec.get("memory", {})
            print(f"  [{tag or 'baseline':>12}] "
                  f"compute={r.get('compute_s', 0):8.4f}s "
                  f"mem={r.get('memory_s', 0):8.4f}s "
                  f"coll={r.get('collective_s', 0):8.4f}s "
                  f"dom={r.get('dominant', '?'):10} "
                  f"mfu={r.get('mfu_bound', 0):.4f} "
                  f"hbm={(m.get('per_device_total_bytes') or 0)/1e9:6.1f}GB",
                  flush=True)


if __name__ == "__main__":
    main()
