"""Serving-tier benchmark (ISSUE 10 acceptance).

Three measurements, each an asserted claim:

* **kernel** — one decode step of attention at long context (``max_len``
  8192, ~1/8 occupancy): the full-cache chunked reference vs the split-KV
  flash-decode lowering whose trip count is bounded by occupancy
  (``flash_decode_xla(bounded=True)``). Work scales with tokens actually
  written, not cache capacity — acceptance: >= 2x at long context. The
  Pallas kernel itself is timed only on TPU (interpret mode is a
  correctness harness, not a performance path) via the same dispatch the
  model uses; CPU CI measures the XLA lowering of the same algorithm.
* **admission** — cost of admitting a request into a slot, paged/lazy
  (release + bind a page, O(pages-touched)) vs the legacy
  ``admission="reset_full"`` cache rebuild (O(cache)). Acceptance: lazy
  admission stays flat as ``max_len`` grows 512 -> 8192 (<= 2x, timer
  noise) while the full reset grows with the cache.
* **replicas** — end-to-end continuous batching through
  ``ServeReplicaSet``, 1 vs 2 replicas on the same workload. The host is a
  single-core CI runner, so each engine models the accelerator-bound
  regime with ``step_latency_s=10ms`` (the sleep releases the GIL outside
  the engine lock, exactly like a device step would): serving-layer
  scaling is then measurable honestly — acceptance: >= 1.5x tokens/s with
  zero lost and zero duplicated requests.

Results land in ``BENCH_serve.json`` next to the repo root so the serving
perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import gc
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

KERNEL_MAX_LEN = 8192
KERNEL_OCCUPANCY = 8           # cache is 1/8 full
KERNEL_ACCEPT_SPEEDUP = 2.0
ADMIT_SHORT, ADMIT_LONG = 512, 8192
ADMIT_FLAT_TOLERANCE = 2.0
REPLICA_ACCEPT_SPEEDUP = 1.5
STEP_LATENCY_S = 0.01

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_serve.json")


def _time(fn, *, reps: int = 20, warmup: int = 3) -> float:
    """Median wall seconds per call (fn must block on its result)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# ---------------------------------------------------------------------------
# kernel: chunked full-cache reference vs occupancy-bounded flash decode
# ---------------------------------------------------------------------------

def _bench_kernel() -> dict:
    from repro.kernels.flash_decode import decode_attention, flash_decode
    from repro.models.attention import chunked_attention

    b, h, kh, dk, s = 4, 8, 8, 64, KERNEL_MAX_LEN
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, 1, h, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kh, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kh, dk)), jnp.float32)
    fill = s // KERNEL_OCCUPANCY
    qpos = jnp.asarray(rng.integers(fill // 2, fill, b), jnp.int32)
    pos = np.tile(np.arange(s, dtype=np.int32), (b, 1))
    valid = pos <= np.asarray(qpos)[:, None]
    kpos = jnp.asarray(np.where(valid, pos, -1))
    k_valid = jnp.asarray(valid)

    chunked = jax.jit(lambda: chunked_attention(
        q, k, v, q_offset=qpos, causal=True, kv_chunk=1024, k_valid=k_valid))
    flash = jax.jit(lambda: decode_attention(q, k, v, qpos, kpos,
                                             bounded=True))
    np.testing.assert_allclose(np.asarray(chunked()), np.asarray(flash()),
                               atol=2e-5)
    t_chunked = _time(lambda: jax.block_until_ready(chunked()))
    t_flash = _time(lambda: jax.block_until_ready(flash()))
    out = {"max_len": s, "occupancy": f"1/{KERNEL_OCCUPANCY}",
           "backend": jax.default_backend(),
           "chunked_us": t_chunked * 1e6, "flash_us": t_flash * 1e6,
           "speedup": t_chunked / t_flash}
    if jax.default_backend() == "tpu":  # compiled Pallas kernel, TPU only
        pallas = jax.jit(lambda: flash_decode(q, k, v, qpos, kpos))
        jax.block_until_ready(pallas())
        out["pallas_us"] = _time(lambda: jax.block_until_ready(pallas())) \
            * 1e6
    assert out["speedup"] >= KERNEL_ACCEPT_SPEEDUP, out
    return out


# ---------------------------------------------------------------------------
# admission: O(pages-touched) lazy vs O(cache) full reset
# ---------------------------------------------------------------------------

def _admit_cost(eng, n: int = 60) -> float:
    """Median seconds per admit+evict cycle (device work blocked on)."""
    gc.collect()  # a stray GC of large cache arrays would skew a cell
    def cycle():
        assert eng.add_request("bench", [1, 2, 3], max_new=4)
        jax.block_until_ready(eng.caches)
        eng.evict("bench")
    return _time(cycle, reps=n, warmup=5)


def _bench_admission() -> dict:
    from repro.configs import smoke_config
    from repro.models import init_params, model_spec
    from repro.serve import ServeEngine

    cfg = smoke_config("stablelm_1_6b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    out: dict = {}
    for mode, kw in (("paged_lazy", dict(paged=True, page_size=64)),
                     ("reset_full", dict(admission="reset_full"))):
        for max_len in (ADMIT_SHORT, ADMIT_LONG):
            eng = ServeEngine(cfg, params, n_slots=4, max_len=max_len, **kw)
            out[f"{mode}_{max_len}_us"] = _admit_cost(eng) * 1e6
    out["lazy_growth"] = (out[f"paged_lazy_{ADMIT_LONG}_us"]
                          / out[f"paged_lazy_{ADMIT_SHORT}_us"])
    out["reset_growth"] = (out[f"reset_full_{ADMIT_LONG}_us"]
                           / out[f"reset_full_{ADMIT_SHORT}_us"])
    assert out["lazy_growth"] <= ADMIT_FLAT_TOLERANCE, out
    return out


# ---------------------------------------------------------------------------
# end to end: 1 vs 2 replicas, same workload
# ---------------------------------------------------------------------------

def _run_workload(cfg, params, n_replicas: int) -> dict:
    from repro.serve import ServeReplicaSet

    rs = ServeReplicaSet(
        cfg, params, n_replicas=n_replicas,
        engine_kw=dict(n_slots=4, max_len=64, paged=True, page_size=16,
                       step_latency_s=STEP_LATENCY_S))
    for eng in rs.engines:  # jit warm-up outside the timed region
        eng.run_until_drained([("warm", [1, 2, 3], 2)])
        eng.tokens_out = 0
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(0, cfg.vocab_size, 4)) for _ in range(24)]
    t0 = time.perf_counter()
    with rs:
        for i, p in enumerate(prompts):
            rs.submit(f"q{i}", p, max_new=8)
        assert rs.drain(timeout=300)
    wall = time.perf_counter() - t0
    tokens = sum(e.tokens_out for e in rs.engines)
    return {"wall_s": wall, "tokens": tokens, "tokens_s": tokens / wall,
            "completed": rs.completed, "lost": rs.lost,
            "duplicates": rs.duplicates}


def _bench_replicas() -> dict:
    from repro.configs import smoke_config
    from repro.models import init_params, model_spec

    cfg = smoke_config("stablelm_1_6b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0),
                         jnp.dtype(cfg.dtype))
    one = _run_workload(cfg, params, 1)
    two = _run_workload(cfg, params, 2)
    out = {"step_latency_s": STEP_LATENCY_S, "r1": one, "r2": two,
           "speedup": two["tokens_s"] / one["tokens_s"]}
    for res in (one, two):
        assert res["completed"] == 24 and res["lost"] == 0, out
        assert res["duplicates"] == 0, out
    assert out["speedup"] >= REPLICA_ACCEPT_SPEEDUP, out
    return out


def bench_serve():
    """run.py entry: (name, us_per_call, derived) rows + BENCH_serve.json."""
    kernel = _bench_kernel()
    admission = _bench_admission()
    replicas = _bench_replicas()
    results = {"kernel": kernel, "admission": admission,
               "replicas": replicas,
               "accept": {
                   "kernel_speedup": kernel["speedup"],
                   "kernel_threshold": KERNEL_ACCEPT_SPEEDUP,
                   "lazy_admission_growth": admission["lazy_growth"],
                   "lazy_admission_threshold": ADMIT_FLAT_TOLERANCE,
                   "replica_speedup": replicas["speedup"],
                   "replica_threshold": REPLICA_ACCEPT_SPEEDUP,
               }}
    with open(_JSON_PATH, "w") as f:
        json.dump(results, f, indent=2)
    return [
        ("serve_kernel_chunked", kernel["chunked_us"],
         f"max_len={kernel['max_len']} occ={kernel['occupancy']}"),
        ("serve_kernel_flash", kernel["flash_us"],
         f"speedup={kernel['speedup']:.1f}x (accept>="
         f"{KERNEL_ACCEPT_SPEEDUP}x)"),
        ("serve_admission_lazy", admission[f"paged_lazy_{ADMIT_LONG}_us"],
         f"growth {ADMIT_SHORT}->{ADMIT_LONG}: "
         f"{admission['lazy_growth']:.2f}x (flat)"),
        ("serve_admission_reset_full",
         admission[f"reset_full_{ADMIT_LONG}_us"],
         f"growth {ADMIT_SHORT}->{ADMIT_LONG}: "
         f"{admission['reset_growth']:.2f}x"),
        ("serve_replicas_e2e", replicas["r2"]["wall_s"] * 1e6,
         f"1->2 replicas {replicas['speedup']:.2f}x tokens/s, "
         f"0 lost/0 dup"),
    ]


if __name__ == "__main__":
    for name, us, derived in bench_serve():
        print(f"{name},{us:.2f},\"{derived}\"")
