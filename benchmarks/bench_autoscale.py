"""Autoscaling benchmark (ISSUE: repro.autoscale tentpole).

A bursty two-class campaign — cpu ``screen`` fan-out feeding a gpu-heavy
``localize`` map stage, submitted in two bursts with an idle gap — run on
three deployments of the same broker code:

* **static** — the paper's layout: pools provisioned once, sized for the
  *average* load (1 cpu worker + 1 gpu worker). Bursts queue behind the
  single gpu slot; the gap leaves the slots idle.
* **peak_static** — pools statically sized for the *peak* (the autoscaler's
  max). Fast, but every slot beyond the average burns idle slot-seconds for
  the whole run (the provisioning cost APACE's elastic AlphaFold serving is
  designed to avoid).
* **autoscaled** — ``KsaCluster(autoscale=...)`` with the same min as
  *static* and the same max as *peak_static*: pools grow on backlog and
  drain back between bursts.

Reported: per-config makespan (sum of burst latencies), **idle-slot-seconds**
(integral of unoccupied slots over the run — the utilization cost of
provisioned-but-idle capacity), and the loss/duplication audit across the
autoscaler's scale-down drains. The acceptance bar (asserted in
tests/test_autoscale.py, reported here): autoscaled ≥ 1.3x faster makespan
than the average-sized static pool with zero lost or duplicated tasks, and
idle-slot-seconds well below the peak-sized static pool.

A ``BENCH_autoscale.json`` summary is written next to the repo root so the
perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import threading
import time

from repro.autoscale import AutoscaleConfig, PoolSpec, TargetBacklogPolicy
from repro.cluster import KsaCluster
from repro.core import Resources
from repro.pipeline import PipelineSpec, RetryPolicy, Stage

N_ITEMS = 32
CPU_TASK_S = 0.02
GPU_TASK_S = 0.08
GAP_S = 1.0
BURSTS = 2

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_autoscale.json")


def _burst_spec() -> PipelineSpec:
    return PipelineSpec("burst", [
        Stage("screen", "sleep", fan_out=1, params={"duration": CPU_TASK_S},
              resources=Resources(cpus=1),
              retry=RetryPolicy(max_attempts=3)),
        Stage("localize", "sleep", depends_on=("screen",),
              params={"duration": GPU_TASK_S},
              resources=Resources(cpus=1, gpus=1),
              retry=RetryPolicy(max_attempts=3)),
    ])


class _IdleSampler:
    """Integrates unoccupied slot-seconds over every live agent (draining
    agents still count — they are provisioned capacity until they stop)."""

    def __init__(self, cluster: KsaCluster, dt: float = 0.01):
        self.cluster = cluster
        self.dt = dt
        self.idle_slot_s = 0.0
        self.slot_s = 0.0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        last = time.perf_counter()
        while not self._stop.is_set():
            time.sleep(self.dt)
            now = time.perf_counter()
            dt, last = now - last, now
            with self.cluster._lock:
                agents = list(self.cluster.agents)
            for a in agents:
                if not a.alive:
                    continue
                s = a.stats()
                self.slot_s += s["slots"] * dt
                self.idle_slot_s += max(0, s["slots"] - s["in_flight"]) * dt

    def stop(self) -> tuple[float, float]:
        self._stop.set()
        self._thread.join(timeout=2.0)
        return self.idle_slot_s, self.slot_s


def _run_config(name: str, **cluster_kw) -> dict:
    with KsaCluster(prefix=f"as-{name}", poll_interval_s=0.005,
                    **cluster_kw) as c:
        sampler = _IdleSampler(c)
        burst_s, done, expect = [], 0, 0
        for b in range(BURSTS):
            t0 = time.perf_counter()
            res = c.run_campaign(_burst_spec(), list(range(N_ITEMS)),
                                 timeout_s=300.0)
            burst_s.append(time.perf_counter() - t0)
            st = res.status
            done += sum(s.done for s in st.stages.values())
            expect += sum(s.expected for s in st.stages.values())
            if b < BURSTS - 1:
                time.sleep(GAP_S)
        # let the autoscaler drain back to min before closing the books
        if c.autoscaler is not None:
            deadline = time.time() + 10.0
            while time.time() < deadline and any(
                    p["agents"] > p["min"] or p["draining"]
                    for p in c.autoscaler.status()["pools"].values()):
                time.sleep(0.02)
        idle_slot_s, slot_s = sampler.stop()
        summary = c.monitor.summary()
        out = {
            "makespan_s": round(sum(burst_s), 3),
            "burst_s": [round(b, 3) for b in burst_s],
            "idle_slot_seconds": round(idle_slot_s, 2),
            "slot_seconds": round(slot_s, 2),
            "tasks_done": done,
            "tasks_expected": expect,
            "lost": expect - done,
            "duplicates_fenced": summary["duplicates_fenced"],
        }
        if c.autoscaler is not None:
            out["scale_ups"] = c.autoscaler.scale_ups
            out["scale_downs"] = c.autoscaler.scale_downs
    return out


def bench_autoscale_burst() -> list[tuple[str, float, str]]:
    policy = TargetBacklogPolicy(target=1.5, high=1.0, idle_grace_s=0.15,
                                 up_cooldown_s=0.1, down_cooldown_s=0.15)
    static = _run_config("st", workers=1, worker_slots=2, gpu_workers=1,
                         gpu_slots=1)
    peak = _run_config("pk", workers=2, worker_slots=2, gpu_workers=4,
                       gpu_slots=1)
    auto = _run_config("au", autoscale=AutoscaleConfig(
        pools=(PoolSpec("cpu", min_agents=1, max_agents=2, slots=2),
               PoolSpec("gpu", min_agents=1, max_agents=4, slots=1)),
        policy=policy, interval_s=0.02))

    speedup = static["makespan_s"] / max(auto["makespan_s"], 1e-9)
    idle_saved = peak["idle_slot_seconds"] - auto["idle_slot_seconds"]
    payload = {
        "bursty_two_class": {
            "n_items": N_ITEMS, "bursts": BURSTS, "gap_s": GAP_S,
            "cpu_task_s": CPU_TASK_S, "gpu_task_s": GPU_TASK_S,
            "static": static, "peak_static": peak, "autoscaled": auto,
            "speedup_vs_static": round(speedup, 2),
            "idle_slot_seconds_saved_vs_peak": round(idle_saved, 2),
        },
    }
    with open(_JSON_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)

    return [
        ("autoscale_static_makespan", static["makespan_s"] * 1e6,
         f"avg-sized static pool: {static['makespan_s']:.2f} s over "
         f"{BURSTS} bursts, {static['idle_slot_seconds']:.1f} idle "
         f"slot-seconds"),
        ("autoscale_peak_static_makespan", peak["makespan_s"] * 1e6,
         f"peak-sized static pool: {peak['makespan_s']:.2f} s but "
         f"{peak['idle_slot_seconds']:.1f} idle slot-seconds provisioned"),
        ("autoscale_elastic_makespan", auto["makespan_s"] * 1e6,
         f"autoscaled: {auto['makespan_s']:.2f} s ({speedup:.1f}x vs "
         f"static; target >= 1.3x), {auto['idle_slot_seconds']:.1f} idle "
         f"slot-seconds ({idle_saved:.1f} below peak-static), "
         f"{auto['scale_ups']} ups / {auto['scale_downs']} downs, "
         f"lost={auto['lost']} dups={auto['duplicates_fenced']}"),
    ]
