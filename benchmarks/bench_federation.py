"""Federation benchmark (ISSUE: repro.federation tentpole).

Two phases over a two-site federation of cost-heterogeneous but
equally-sized pools (site ``b`` is behind a modeled WAN link and charges a
cold-start + premium slot cost, which is exactly what the spill score
weighs):

* **spillover makespan** — one bursty campaign of identical tasks run
  three ways: on site ``a`` alone, on site ``b`` alone (the best
  single-site deployment either way), and federated with the
  :class:`~repro.federation.SpilloverController` borrowing site ``b``'s
  capacity when site ``a``'s backlog outruns its drain rate. Acceptance
  (asserted here): federated beats the best single-site makespan by
  >= 1.5x with **zero lost and zero double-run** tasks.
* **WAN partition recovery** — a campaign pinned to the remote site with a
  mid-campaign link partition longer than the uniform watchdog deadline.
  The per-site :class:`~repro.core.lease.LeaseTolerance` keeps the home
  control plane from revoking the healthy-but-unreachable leases;
  acceptance: every task completes on its first attempt after the link
  heals (result parity, no watchdog revocations, no duplicates).

A ``BENCH_federation.json`` summary is written next to the repo root so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

from repro.cluster import KsaCluster
from repro.core.lease import LeaseTolerance, RevokeReason
from repro.federation import FederatedCluster, Site, SpilloverConfig, WanLink

N_TASKS = 120
TASK_S = 0.15
SLOTS_PER_SITE = 6          # 3 workers x 2 slots at each site
PARTITIONS = 12             # 2 per member once 3 spill bridges join

N_PINNED = 12
PINNED_TASK_S = 0.15
PARTITION_S = 0.8           # > the uniform watchdog deadline below

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_federation.json")


# both runs use the balanced partitioner: under the sticky group assignor
# the makespan is set by the most-loaded member, so keyed-hash skew would
# dominate what this benchmark is trying to measure
_TUNING = {"default_partitions": PARTITIONS, "partitioner": "balanced"}


def _site_a() -> Site:
    return Site("a", workers=3, worker_slots=2,
                cluster_kw={**_TUNING, "poll_interval_s": 0.005})


def _site_b() -> Site:
    # same slot count, different economics: a WAN away, slower to warm up,
    # and pricier per slot-second — the spill decision pays all three
    return Site("b", workers=3, worker_slots=2, spinup_s=0.1, slot_cost=1.2,
                link=WanLink(latency_s=0.002, bandwidth_mbps=1000.0),
                cluster_kw=dict(_TUNING))


def _drain(cluster: KsaCluster, n: int) -> dict:
    t0 = time.perf_counter()
    tids = [cluster.submit("sleep", params={"duration": TASK_S},
                           timeout_s=60.0) for _ in range(n)]
    assert cluster.wait_all(tids, timeout=240.0), "single-site run stalled"
    dt = time.perf_counter() - t0
    done = sum(1 for t in tids if cluster.result(t) is not None)
    return {"makespan_s": round(dt, 3), "done": done}


def _single_site(name: str, site: Site) -> dict:
    with KsaCluster(prefix=f"fed1-{name}", poll_interval_s=0.005,
                    workers=site.workers, worker_slots=site.worker_slots,
                    **_TUNING) as c:
        return _drain(c, N_TASKS)


def _federated() -> dict:
    spill = SpilloverConfig(classes=("cpu",), horizon_s=0.1, min_backlog=1,
                            interval_s=0.01, cooldown_s=0.01,
                            drain_idle_s=0.3, bridge_slots=3,
                            max_bridges_per_class=3, est_run_s=TASK_S)
    with FederatedCluster([_site_a(), _site_b()], prefix="fedN",
                          spillover=spill, remote_poll_s=0.002,
                          poll_interval_s=0.005) as fed:
        t0 = time.perf_counter()
        tids = [fed.submit("sleep", params={"duration": TASK_S},
                           timeout_s=60.0) for _ in range(N_TASKS)]
        assert fed.wait_all(tids, timeout=240.0), "federated run stalled"
        dt = time.perf_counter() - t0
        done = sum(1 for t in tids if fed.result(t) is not None)
        dups = sum(fed.task(t).duplicate_results for t in tids)
        summary = fed.home.monitor.summary()
        spills = fed.spillover.status()["classes"]["cpu"]["spills"]
        relayed = sum(b_.tasks_completed for b_ in fed.bridges("b"))
    return {"makespan_s": round(dt, 3), "done": done, "lost": N_TASKS - done,
            "duplicates": dups + summary["duplicates_fenced"],
            "spill_bridges_raised": spills, "relayed_done": relayed}


def _partition_recovery() -> dict:
    """Mid-campaign WAN partition on the remote site; the stretched lease
    deadline rides it out and every pinned task completes exactly once."""
    b = Site("b", workers=2, worker_slots=2,
             tolerance=LeaseTolerance(slack_s=60.0))
    # bridge_slots covers the whole campaign so every task already holds a
    # WAN-tolerant lease when the link drops — queued-but-unleased tasks
    # would (correctly) be resubmitted by the at-least-once watchdog
    with FederatedCluster([Site("a", workers=1), b], prefix="fedP",
                          task_timeout_s=0.5, bridge_slots=N_PINNED,
                          poll_interval_s=0.005) as fed:
        t0 = time.perf_counter()
        tids = [fed.submit("sleep", params={"duration": PINNED_TASK_S},
                           site="b") for _ in range(N_PINNED)]
        time.sleep(0.2)                      # campaign under way
        b.link.partition()
        time.sleep(PARTITION_S)              # > task_timeout_s of 0.5
        b.link.heal()
        completed = fed.wait_all(tids, timeout=120.0)
        dt = time.perf_counter() - t0
        entries = [fed.task(t) for t in tids]
        first_attempt = sum(1 for e in entries if e.result_attempt == 0)
        dups = sum(e.duplicate_results for e in entries)
        revoked = fed.home.broker.lease_stats()["revoked"]
        watchdog = revoked.get(RevokeReason.WATCHDOG, 0)
    return {"completed": completed, "elapsed_s": round(dt, 3),
            "tasks": N_PINNED,
            "first_attempt_results": first_attempt,
            "duplicates": dups, "watchdog_revocations": watchdog,
            "partition_s": PARTITION_S}


def bench_federation() -> list[tuple[str, float, str]]:
    single_a = _single_site("a", _site_a())
    single_b = _single_site("b", _site_b())
    fed = _federated()
    best_single = min(single_a["makespan_s"], single_b["makespan_s"])
    speedup = best_single / max(fed["makespan_s"], 1e-9)

    # acceptance: spillover beats the best single site >= 1.5x, losing and
    # double-running nothing
    assert speedup >= 1.5, \
        (f"federated {fed['makespan_s']:.2f}s vs best single "
         f"{best_single:.2f}s = {speedup:.2f}x (< 1.5x)")
    assert fed["lost"] == 0, fed
    assert fed["duplicates"] == 0, fed

    part = _partition_recovery()
    # acceptance: the partitioned campaign recovers to completion with
    # result parity — every task, first attempt, no duplicate verdicts
    assert part["completed"], part
    assert part["first_attempt_results"] == part["tasks"], part
    assert part["duplicates"] == 0 and part["watchdog_revocations"] == 0, part

    payload = {
        "spillover_makespan": {
            "n_tasks": N_TASKS, "task_s": TASK_S,
            "slots_per_site": SLOTS_PER_SITE,
            "single_site_a": single_a, "single_site_b": single_b,
            "federated": fed,
            "speedup_vs_best_single": round(speedup, 2),
        },
        "partition_recovery": part,
    }
    with open(_JSON_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)

    return [
        ("federation_single_site_makespan", best_single * 1e6,
         f"best single site: {best_single:.2f} s for {N_TASKS} tasks on "
         f"{SLOTS_PER_SITE} slots"),
        ("federation_spillover_makespan", fed["makespan_s"] * 1e6,
         f"federated: {fed['makespan_s']:.2f} s ({speedup:.2f}x vs best "
         f"single; target >= 1.5x), {fed['spill_bridges_raised']} spill "
         f"bridges, {fed['relayed_done']} tasks relayed, "
         f"lost={fed['lost']} dups={fed['duplicates']}"),
        ("federation_partition_recovery", part["elapsed_s"] * 1e6,
         f"{part['partition_s']:.1f}s WAN partition mid-campaign: "
         f"{part['first_attempt_results']}/{part['tasks']} tasks completed "
         f"on their first attempt after heal, "
         f"watchdog_revocations={part['watchdog_revocations']}, "
         f"dups={part['duplicates']}"),
    ]
