"""Observability overhead benchmarks.

The obs layer (repro.obs) records a histogram observation and 4-6 spans per
task on the control plane's hot path. The contract that keeps it always-on
by default is a hard overhead ceiling: tracing + metrics must cost at most
5% of end-to-end wall time on the no-op pipeline DAG from
``bench_pipeline`` — the configuration where orchestration overhead is the
*entire* cost, i.e. the worst case for the obs layer. Real campaigns (tasks
that do work) amortize this to noise.

The telemetry *plane* (ISSUE 9: publisher + collector + time-series store +
alert engine, all streaming over the broker's PREFIX-telemetry topic) has
its own ceiling: at most 10% end-to-end on the same no-op DAG, measured as
``KsaCluster(telemetry=True)`` vs ``telemetry=False`` with obs on in both.

Method: the same 64-task two-stage no-op campaign per mode; each mode takes
the minimum of three runs (minimum, not mean — scheduler noise only ever
adds time). The ratios are asserted and written to ``BENCH_obs.json``
(``noop_dag_overhead`` / ``telemetry_overhead``) so the perf trajectory
tracks both taxes across PRs.
"""
from __future__ import annotations

import json
import os
import time

from repro.cluster import KsaCluster
from repro.obs import AlertRule, SloSpec
from repro.pipeline import PipelineSpec, Stage

N_TASKS = 64
REPEATS = 3
OVERHEAD_CEILING = 0.05
TELEMETRY_CEILING = 0.10

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json")


def _spec() -> PipelineSpec:
    return PipelineSpec("obs-noop", [
        Stage("a", "sleep", fan_out=1, params={"duration": 0.0}),
        Stage("b", "sleep", depends_on=("a",), params={"duration": 0.0}),
    ])


def _run_once(tag: str, obs: bool, telemetry: bool = False) -> float:
    slos = ()
    if telemetry:
        # a live rule so the alert engine actually evaluates every tick
        slos = (AlertRule(
            slo=SloSpec(name="qw-p95",
                        metric="ksa_task_queue_wait_seconds:p95",
                        objective=30.0, q=0.95)),)
    with KsaCluster(prefix=f"bo-{tag}", workers=1, worker_slots=4,
                    poll_interval_s=0.002, obs=obs, telemetry=telemetry,
                    telemetry_interval_s=0.05, slos=slos) as c:
        t0 = time.perf_counter()
        cid = c.submit_campaign(_spec(), list(range(N_TASKS)))
        st = c.wait_campaign(cid, timeout=120.0)
        wall = time.perf_counter() - t0
        assert st.state == "COMPLETED", st.failure
        if obs:
            # the instrumented run must actually have instrumented: spans
            # for every task and populated latency histograms
            text = c.broker.metrics.render()
            assert "ksa_task_run_seconds_count" in text
            assert c.broker.spans.stats()["tasks"] >= N_TASKS
        if telemetry:
            # the plane must actually have streamed: records on the topic,
            # series in the store, and at least one alert evaluation
            c.telemetry_publisher.publish_once()
            c.telemetry_collector.poll()
            assert c.telemetry_store.sum("ksa_leases_completed_total") > 0
            c.alert_engine.evaluate()
            assert c.alerts()["rules"] == ["qw-p95"]
    return wall


def bench_obs_overhead() -> list[tuple[str, float, str]]:
    base = min(_run_once(f"off{i}", obs=False) for i in range(REPEATS))
    traced = min(_run_once(f"on{i}", obs=True) for i in range(REPEATS))
    overhead = traced / max(base, 1e-9) - 1.0

    # acceptance: tracing + metrics cost <= 5% wall on the no-op DAG
    assert overhead <= OVERHEAD_CEILING, (
        f"obs overhead {overhead:.1%} exceeds {OVERHEAD_CEILING:.0%} "
        f"(base {base:.3f}s, traced {traced:.3f}s)")

    # telemetry-plane mode: publisher + collector + alert engine on vs off
    # (obs on in both, so this isolates the streaming plane's tax)
    streamed = min(_run_once(f"tp{i}", obs=True, telemetry=True)
                   for i in range(REPEATS))
    t_overhead = streamed / max(traced, 1e-9) - 1.0
    assert t_overhead <= TELEMETRY_CEILING, (
        f"telemetry overhead {t_overhead:.1%} exceeds "
        f"{TELEMETRY_CEILING:.0%} (obs-only {traced:.3f}s, "
        f"telemetry {streamed:.3f}s)")

    payload = {
        "noop_dag_overhead": {
            "tasks": N_TASKS,
            "stages": 2,
            "repeats": REPEATS,
            "wall_obs_off_s": round(base, 4),
            "wall_obs_on_s": round(traced, 4),
            "overhead_frac": round(overhead, 4),
            "ceiling": OVERHEAD_CEILING,
        },
        "telemetry_overhead": {
            "tasks": N_TASKS,
            "stages": 2,
            "repeats": REPEATS,
            "wall_telemetry_off_s": round(traced, 4),
            "wall_telemetry_on_s": round(streamed, 4),
            "overhead_frac": round(t_overhead, 4),
            "ceiling": TELEMETRY_CEILING,
        },
    }
    with open(_JSON_PATH, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)

    per_task_us = traced / N_TASKS * 1e6
    return [
        ("obs_overhead", per_task_us,
         f"tracing+metrics on {N_TASKS}-task no-op DAG: "
         f"{traced:.3f}s vs {base:.3f}s untraced "
         f"({overhead:+.1%}; ceiling {OVERHEAD_CEILING:.0%})"),
        ("telemetry_overhead", streamed / N_TASKS * 1e6,
         f"publisher+collector+alerts on {N_TASKS}-task no-op DAG: "
         f"{streamed:.3f}s vs {traced:.3f}s obs-only "
         f"({t_overhead:+.1%}; ceiling {TELEMETRY_CEILING:.0%})"),
    ]
