"""Serving a small model with batched requests through the KSA broker —
the AlphaKnot-2.0 web-service pattern (paper §4) applied to LM inference.

Requests are routed by resource class: ``serve_request`` tasks declare
``gpus=1`` and land only on the engine-owning (GPU-profiled) worker, while
tokenize/post-process stages run on the CPU pool — the ParaFold stage split,
wired end to end through one :class:`~repro.cluster.KsaCluster`.

Part 2 runs the same workload as a repro.pipeline DAG — tokenize (fan-out) →
generate (serve_request as a map stage) → post-process (join) — proving the
campaign subsystem is workload-agnostic.

For the production tier, replicate instead of batching through one engine:
``ServeReplicaSet(cfg, params, n_replicas=N, engine_kw=dict(paged=True,
decode_kernel="flash"), ttft_slo=ttft_slo(0.5), on_violation="shed")``
routes each request to the replica with the least projected queue wait
(token rate from the telemetry store), sheds or spills when even the best
replica would blow the TTFT budget, and ``deploy(cluster, taint="serve")``
runs every replica driver as a long-lived task on a serve-tainted worker
pool (requires ``placement=ResourceClassPolicy(extra_classes=("serve",))``)
with ``serve_loadgen`` tasks as the load-generation campaign — see
tests/test_serve.py::test_replica_set_cluster_deploy and
benchmarks/bench_serve.py for both wirings end to end.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import KsaCluster
from repro.configs import smoke_config
from repro.core import ResourceProfile
from repro.models import init_params, model_spec
from repro.serve import ServeEngine, serve_pipeline
from repro.serve.engine import ServeRequestComputing


def main() -> None:
    cfg = smoke_config("stablelm_1_6b")
    params = init_params(model_spec(cfg), jax.random.PRNGKey(0),
                        jnp.dtype(cfg.dtype))
    # attach the engine to the serving task class (one engine per process)
    ServeRequestComputing.engine = ServeEngine(cfg, params, n_slots=4,
                                               max_len=96)

    with KsaCluster(prefix="srv", workers=1, default_partitions=2) as c:
        # the model-owning pool: one GPU-profiled slot, so generate tasks
        # queue here and never oversubscribe the single engine
        c.add_worker(slots=1, profile=ResourceProfile(cpus=2, gpus=1))

        rng = np.random.RandomState(0)
        reqs = [{"id": f"user{i}",
                 "prompt": [int(t) for t in rng.randint(0, cfg.vocab_size,
                                                        4 + i % 4)],
                 "max_new": 8}
                for i in range(8)]
        t0 = time.time()
        tid = c.submit("serve_request", params={"requests": reqs},
                       gpus=1, timeout_s=600.0)
        assert c.wait_all([tid], timeout=900.0)
        res = c.result(tid)
        dt = time.time() - t0
        print(f"served {len(res['results'])} requests in {dt:.1f}s "
              f"({res['tokens_per_s']:.1f} tok/s inside the engine)")
        for rid, toks in sorted(res["results"].items())[:4]:
            print(f"  {rid}: {toks}")

        # -- part 2: the same workload as a 3-stage pipeline ----------------
        texts = [{"id": f"pipe{i}", "text": f"fold protein number {i}",
                  "max_new": 6} for i in range(8)]
        spec = serve_pipeline(batch_size=4, vocab_size=cfg.vocab_size,
                              max_new=6)
        t0 = time.time()
        camp = c.run_campaign(spec, texts, timeout_s=900.0)
        agg = camp.final
        print(f"\npipeline served {agg['n_requests']} requests "
              f"({agg['total_tokens']} tokens) in {time.time()-t0:.1f}s via "
              f"{[s.name for s in spec.topological()]}")
        for rid, r in list(agg["responses"].items())[:4]:
            print(f"  {rid}: {r['tokens']}")
        assert agg["n_requests"] == len(texts)
    print("OK")


if __name__ == "__main__":
    main()
