"""Fault-tolerant distributed training through the KSA control plane.

A training run is a chain of idempotent step-chunk tasks (checkpoint →
n steps → checkpoint) distributed over agents; killing an agent mid-chunk
loses nothing: the monitor's watchdog resubmits and a surviving agent resumes
from the last checkpoint with bit-identical data (deterministic offset-
addressable stream). All wiring goes through the KsaCluster facade.

Run:  PYTHONPATH=src python examples/train_ft.py                # smoke scale
      PYTHONPATH=src python examples/train_ft.py --preset 130m  # mamba2-130m
"""
import argparse
import tempfile
import threading
import time

from repro.cluster import KsaCluster
from repro.train import trainer  # noqa: F401 - registers "train_chunk"
from repro.train.trainer import TrainCampaign


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["smoke", "130m"], default="smoke")
    ap.add_argument("--arch", default="mamba2_130m")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--kill-agent", action="store_true", default=True)
    args = ap.parse_args()

    with KsaCluster(prefix="tr", task_timeout_s=120.0, max_attempts=4,
                    session_timeout_s=1.0, default_partitions=2,
                    agent_kw=dict(heartbeat_interval_s=0.2)) as c:
        a1 = c.add_worker(slots=1)
        c.add_worker(slots=1)

        ckpt_dir = tempfile.mkdtemp(prefix="ksa_train_")
        campaign = TrainCampaign(
            c.broker, c.submitter, c.monitor, arch=args.arch,
            ckpt_dir=ckpt_dir, total_steps=args.steps,
            chunk_steps=args.chunk, batch=4, seq=64, timeout_s=600.0)
        # smoke preset uses the reduced config; 130m uses the full one
        if args.preset == "130m":
            # full mamba2-130m: slower on CPU; fewer, bigger chunks
            campaign.chunk_steps = max(args.chunk // 2, 2)

        if args.kill_agent:
            def assassin():
                time.sleep(3.0)
                print("!! killing agent 1 mid-campaign")
                a1.crash()
            threading.Thread(target=assassin, daemon=True).start()

        t0 = time.time()
        out = campaign.run(wait_timeout=1800.0)
        dt = time.time() - t0
        print(f"\ntrained to step {out['final_step']} in {dt:.1f}s "
              f"across {out['chunks']} chunks; "
              f"final loss {out['final_loss']:.4f}")
        print("losses by chunk:", [round(r["loss"], 4)
                                   for r in campaign.chunk_results])
        print("monitor summary:", c.monitor.summary())
        print(f"checkpoints in {ckpt_dir}")
    print("OK")


if __name__ == "__main__":
    main()
