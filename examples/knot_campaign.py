"""The paper's application end-to-end: an AlphaKnot-style knot-detection
campaign over synthetic protein backbones, with a mid-campaign agent failure
(straggler mitigation / at-least-once redelivery in action).

Structures are processed in batches (paper §4: batches of 4000 across 3
clusters; here scaled to the container) through the two-stage pipeline:
writhe/ACN screen → knot-core localization.

Run:  PYTHONPATH=src python examples/knot_campaign.py [--structures 128]
"""
import argparse
import time

from repro.apps import knots  # registers the "knot_batch" script
from repro.core import Broker, MonitorAgent, SimSlurm, ClusterAgent, \
    Submitter, WorkerAgent


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--structures", type=int, default=96)
    ap.add_argument("--batch-size", type=int, default=12)
    ap.add_argument("--n-points", type=int, default=96)
    args = ap.parse_args()

    broker = Broker(default_partitions=4, session_timeout_s=2.0)
    sub = Submitter(broker, "alphaknot")
    mon = MonitorAgent(broker, "alphaknot", task_timeout_s=60.0,
                       max_attempts=4).start()
    slurm = SimSlurm(nodes=2, cpus_per_node=1)
    agents = [
        ClusterAgent(broker, slurm, "alphaknot", oversubscribe=2).start(),
        WorkerAgent(broker, "alphaknot", slots=1,
                    heartbeat_interval_s=0.2).start(),
    ]

    ids = list(range(args.structures))
    t0 = time.time()
    tids = sub.submit_batches("knot_batch", ids, batch_size=args.batch_size,
                              params={"n_points": args.n_points,
                                      "stage2": True},
                              timeout_s=120.0)
    print(f"campaign: {len(ids)} structures in {len(tids)} batch tasks "
          f"across 1 cluster + 1 workstation")

    # inject a failure once the campaign is under way (paper-motivating
    # scenario: a node dies mid-campaign; the watchdog redelivers)
    time.sleep(1.0)
    print("!! killing the workstation agent mid-campaign")
    agents[1].crash()

    assert mon.wait_all(tids, timeout=900.0), "campaign stalled"
    dt = time.time() - t0

    knotted, cores, processed = [], {}, 0
    for t in tids:
        r = mon.task(t).result
        processed += r["processed"]
        knotted += r["knotted"]
        cores.update(r["cores"])
    print(f"\nprocessed {processed} structures in {dt:.1f}s "
          f"({processed/dt:.1f}/s) despite the failure")
    print(f"knotted: {len(knotted)} "
          f"(expected ~{int(args.structures * 0.75 * 0.85)} — "
          f"3 of 4 families are knotted, minus pLDDT-style drops)")
    sample = list(cores.items())[:5]
    for sid, (a, b) in sample:
        print(f"  structure {sid}: knot core ≈ residues [{a}, {b})")
    print("monitor summary:", mon.summary())

    for a in agents:
        a.stop()
    mon.stop()
    slurm.shutdown()
    broker.close()
    print("OK")


if __name__ == "__main__":
    main()
