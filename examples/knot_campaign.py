"""The paper's application as a DAG campaign: an AlphaKnot-style 3-stage
pipeline (screen → localize → aggregate) over synthetic protein backbones,
with a mid-campaign agent failure (straggler mitigation / at-least-once
redelivery in action) and a flat-baseline parity check.

Stage 1 fans structures out into screening batches (paper §4: batches of 4000
across 3 clusters; here scaled to the container), stage 2 localizes knot
cores on the survivors of each batch — skipped entirely for batches with no
survivors (conditional edge), stage 3 is a join barrier aggregating the
campaign. Both the campaign and the flat baseline run through the
:class:`~repro.cluster.KsaCluster` facade on one shared broker.

Durability — surviving the *orchestrator* dying, not just a worker
------------------------------------------------------------------
Campaign progress is event-sourced: before acting, the pipeline agent
appends a typed journal event to the ``PREFIX-campaigns`` topic, so the
broker (the paper's one shared piece of infrastructure) holds the full DAG
history. The journal records, in per-campaign ``seq`` order::

    {"kind": "journal", "type": <event>, "campaign_id": ..., "seq": n,
     "ts": ..., "data": {...}}

    CampaignSubmitted {pipeline, items, params, weight}   campaign exists
    StageDispatched   {stage, task_id, index, params,     one task planned
                       dep_ids}
    LeaseGranted      {task_id, attempt}                  one (re)submission
    LeaseRevoked      {task_id, reason}                   lease taken back
    TaskDone          {task_id, result}                   first result wins
    TaskFailed        {task_id, reason, cause, final}     error / exhaustion
    StageSkipped      {stage, task_id, index, dep_ids}    conditional edge
    BarrierReleased   {stage}                             join fired once

Telemetry rides the broker the same way (``KsaCluster(telemetry=True)``):
a :class:`~repro.obs.TelemetryPublisher` streams metric/span/event
snapshots onto the durable ``PREFIX-telemetry`` topic, one record per
tick, keyed by source::

    {"kind": "telemetry", "v": 1, "source": ..., "site": ..., "seq": n,
     "ts": ...,
     "metrics": [{"name", "type", "labels", "value"}          # counter/gauge
                 | {"name", "type": "histogram", "labels",
                    "count", "sum", "p50", "p95", "p99"}],
     "spans":  [...],       # new spans since the last tick
     "events": [...]}       # new flight-recorder events since the last tick

A :class:`~repro.obs.TelemetryCollector` (attached to the monitor) replays
the topic via the same group-less ``Broker.read_from`` the journal uses
and folds it into a queryable :class:`~repro.obs.TimeSeriesStore` —
histograms become ``{name}_count``/``{name}_sum`` plus ``:p50/:p95/:p99``
recording-rule series, so an SLO on queue-wait p95 targets
``ksa_task_queue_wait_seconds:p95``. Like the journal, the topic is the
source of truth: kill the monitor and a restarted collector rebuilds the
exact same store from offset 0. ``GET /query`` / ``cluster.query(...)``
aggregate it (``latest``/``rate``/``quantile``/``sum_by``/``points``);
``SloSpec``/``AlertRule`` burn-rate rules evaluate against it
(``GET /alerts``); the broker's always-on flight recorder keeps a bounded
blackbox of grants/revocations/drains/spills that auto-dumps a
post-mortem on a revocation storm, campaign FAILED, or firing alert
(``GET /blackbox``, forced via ``cluster.dump_blackbox()``) — all shown
at the end of this example.

Lease lifecycle — how work is taken *back*
------------------------------------------
Every task an agent accepts holds a broker-tracked lease
(``repro.core.lease``): GRANTED → RUNNING → DONE/FAILED, or
REVOKED(reason) when the control plane reclaims the slot. Revocation
reasons: ``watchdog`` (hung/stale task — agent and monitor watchdogs),
``drain`` (graceful agent removal / autoscale shrink), ``scancel``
(Slurm walltime or operator cancel — also ``KsaCluster.revoke(task_id)``),
``mem_overage`` (the task's reported RSS exceeded its ``Resources.mem_mb``
request), and ``preempt`` (fair-share preemption, below).
``Broker.revoke_lease`` fires the task's ``check_cancel``, fences the old
holder's result at the commit gate, and requeues the record atomically —
which is why the knot stages thread ``check_cancel`` through every
O(chain-length) loop: a revoked localization stops within one shrink step,
not after the whole batch. Campaign revocations are journaled
(``LeaseRevoked`` above) so ``recover()`` replays them like completions.

Preemptive FairShare knobs: ``KsaCluster(lease=FairShare(preempt_factor=
2.0))`` names a campaign holding more than ``preempt_factor`` times its
weighted share of in-flight leases while a peer with ready work is
starved; ``RetryPolicy(max_preemptions=N)`` on a stage opts the campaign
in (the bound is per campaign, the max over its stages, and preemptions
do not consume the ``max_attempts`` retry budget). See
``benchmarks/bench_preemption.py`` for the over-share tail-latency win.

If this process is ``kill -9``'d mid-campaign, a fresh process on the same
broker resumes it::

    with KsaCluster(prefix="alphaknot", broker=broker) as c2:
        c2.recover([knots.knots_pipeline(batch_size)])  # specs are code —
        c2.wait_campaign(campaign_id)                   # re-supply them

``recover()`` folds each live campaign's journal through the pure
``CampaignState`` reducer, repairs any gap a crash left between journal
writes, resubmits only tasks with **no terminal event** (on the same
journaled retry budget the dead orchestrator was using), absorbs results
that landed while no orchestrator was alive, and re-fences duplicates —
the campaign finishes COMPLETED with the same knot counts as an
uninterrupted run (asserted in tests/test_pipeline.py). The monitor's
``/campaigns`` endpoint shows each campaign's journal tally and
``recovered`` flag.

Autoscaled mode (``--autoscale``)
---------------------------------
With ``--autoscale`` the static pools are replaced by
``KsaCluster(autoscale=AutoscaleConfig(...))`` (see :mod:`repro.autoscale`)
and the localize stage requests a GPU (``knots_pipeline(gpu_localize=True)``,
the ParaFold CPU-screen/GPU-predict split): a controller watches each
resource class's queue depth on its ``PREFIX-new.<class>`` topic and grows
the cpu/gpu pools while the campaign bursts, then shrinks them back to the
floor through graceful drains (in-flight tasks finish, deferred leases are
requeued — knot counts still match the flat baseline exactly). The
monitor's ``GET /autoscale`` endpoint serves the controller's live state:
per-pool membership, backlog history samples ``[ts, backlog, agents,
in_flight]``, and the decision log (scale-up/down events with reasons) —
the same observability surface §3 gives tasks.

Observability — where did the campaign's wall time go?
------------------------------------------------------
Every hop records into the broker's metrics registry and span store
(:mod:`repro.obs`). The monitor serves ``GET /metrics`` — Prometheus text;
``ksa_``-prefixed, timed metrics end ``_seconds``, per-resource-class
latencies (queue wait, grant→claim, run, result commit) carry a ``cls``
label matching the class topic suffix (``cpu``/``gpu``, ``flat`` for the
single-topic layout), lifecycle counters use ``event``/``reason`` labels —
and ``GET /trace/<task_id>`` — the task's full span chain, ``submit →
grant (duration = queue wait) → claim → run → commit``, with revocations
and retries linked under the same task id across attempts. In-process the
same data is ``c.trace(task_id)``, ``c.metrics_text()``, and
``c.campaign_report(campaign_id)`` — the per-stage critical path: queue vs
run vs retry seconds and the dominant stage (printed at the end of this
example). ``KsaCluster(obs=False)`` turns off histograms and spans
(counters stay live — the ``status()`` views read through them); the
always-on default costs ≤5% even on a no-op DAG
(``benchmarks/bench_obs.py``).

Federated mode (``--sites 2``)
------------------------------
With ``--sites 2`` the same campaign runs on a two-site
:class:`~repro.federation.FederatedCluster`: a small home site (``edge``,
where submissions enter) plus a bigger remote HPC pool behind a modeled
WAN link. Each site keeps its own broker/pools/monitor; remote work flows
only through bridge relays holding *home* leases, so exactly-once
commits and ``KsaCluster``-style recovery carry over unchanged. The knobs
this mode demonstrates:

* **Site affinity** — ``knots_pipeline(localize_site="hpc")`` pins the
  kernel-heavy localize stage to the remote site via ``Resources.site``
  (flat tasks: ``fed.submit(..., site="hpc", input_mb=...)``;
  ``input_mb`` weighs data locality in spill pricing and WAN transfer
  time). Unpinned stages (screen, aggregate) stay site-free.
* **Cost-aware spillover** — ``SpilloverConfig(horizon_s=...)`` spills a
  class when its home backlog would outlive the horizon at the observed
  drain rate; the cheapest reachable site wins
  (``SiteRouter.spill_score``: ``Site.spinup_s`` cold-start +
  ``Site.slot_cost`` slot-seconds + WAN transfer over ``Site.link``).
  ``min_backlog``/``cooldown_s`` pace the bridges,
  ``max_bridges_per_class`` caps them, ``drain_idle_s`` hands capacity
  back.
* **WAN-tolerant leases** — ``Site(tolerance=LeaseTolerance(slack_s=...,
  rtt_factor=...))`` stretches only that site's lease deadlines, so a slow
  link does not trip the home watchdog while partitions heal.

The home monitor serves the whole federation: ``GET /sites`` (per-site
brokers, leases, bridges, spillover decisions) and a ``GET /metrics``
where every sample carries a ``site`` label.

Run:  PYTHONPATH=src python examples/knot_campaign.py [--structures 128]
                                                      [--autoscale]
                                                      [--sites 2]
"""
import argparse
import json
import threading
import time
import urllib.error
import urllib.request

from repro.apps import knots  # registers knot_* scripts
from repro.cluster import KsaCluster
from repro.core import Broker
from repro.obs import SloSpec


def flat_baseline(broker: Broker, structures: int, batch_size: int,
                  n_points: int) -> dict:
    """The pre-pipeline flat submission (one bag of knot_batch tasks),
    used to check the campaign reports identical knot counts."""
    with KsaCluster(prefix="flat", broker=broker) as c:
        for _ in range(2):
            c.add_worker(slots=1)
        ids = list(range(structures))
        t0 = time.time()
        tids = c.submit_batches("knot_batch", ids, batch_size=batch_size,
                                params={"n_points": n_points, "stage2": True})
        assert c.wait_all(tids, timeout=900.0), "flat baseline stalled"
        dt = time.time() - t0
        knotted, cores = set(), {}
        for t in tids:
            r = c.result(t)
            knotted.update(r["knotted"])
            cores.update(r["cores"])
    return {"knotted": sorted(knotted), "cores": cores, "elapsed_s": dt}


def federated_main(args) -> None:
    """--sites 2: the campaign on an edge + HPC federation (see the
    'Federated mode' docstring section for the knobs shown here)."""
    from repro.federation import (FederatedCluster, Site, SpilloverConfig,
                                  WanLink)
    sites = [
        Site("edge", workers=2, worker_slots=1,
             cluster_kw={"pipeline_task_timeout_s": 20.0,
                         "partitioner": "balanced",
                         "default_partitions": 8}),
        Site("hpc", workers=2, worker_slots=2, spinup_s=0.5, slot_cost=1.5,
             link=WanLink(latency_s=0.01, bandwidth_mbps=500.0),
             cluster_kw={"partitioner": "balanced",
                         "default_partitions": 8}),
    ]
    spill = SpilloverConfig(classes=("cpu",), horizon_s=0.3, min_backlog=2,
                            interval_s=0.05, cooldown_s=0.2,
                            drain_idle_s=0.5, bridge_slots=2,
                            max_bridges_per_class=2)
    with FederatedCluster(sites, prefix="alphaknot", http=True,
                          spillover=spill) as fed:
        spec = knots.knots_pipeline(args.batch_size, n_points=args.n_points,
                                    task_timeout_s=20.0,
                                    localize_site="hpc")
        ids = list(range(args.structures))
        print(f"federated campaign: {len(ids)} structures, home=edge "
              f"(2x1 slots), remote=hpc (2x2 slots, 10ms WAN); localize "
              f"pinned to hpc, screen spills on backlog")
        res = fed.run_campaign(spec, ids, timeout_s=900.0)
        agg = res.final
        print(f"\nprocessed {agg['processed']} structures in "
              f"{res.elapsed_s:.1f}s -> state {res.status.state}")
        print(f"knotted: {len(agg['knotted'])}")

        with urllib.request.urlopen(
                f"http://127.0.0.1:{fed.http_port}/sites") as r:
            payload = json.loads(r.read())
        for name, s in payload["sites"].items():
            roles = [b["role"] for b in s["bridges"]]
            print(f"site {name}{' (home)' if s['home'] else ''}: "
                  f"leases completed {s['leases']['completed']}, "
                  f"bridges {roles or '[]'}")
        for d in payload.get("spillover", {}).get("decisions", [])[-4:]:
            print(f"  spillover: {d['action']} {d['cls']} -> {d['site']} "
                  f"({d['reason']})")
        relayed = sum(b.tasks_completed for b in fed.bridges())
        site_lines = sum(1 for ln in fed.metrics_text().splitlines()
                         if 'site="hpc"' in ln)
        print(f"{relayed} tasks relayed over the WAN; federated /metrics "
              f"has {site_lines} hpc-labelled samples")

        if not args.skip_baseline:
            base = flat_baseline(fed.home.broker, args.structures,
                                 args.batch_size, args.n_points)
            match = base["knotted"] == agg["knotted"]
            print(f"flat baseline: {len(base['knotted'])} knotted — counts "
                  f"{'MATCH' if match else 'MISMATCH'}")
            assert match, (base["knotted"], agg["knotted"])
            assert set(base["cores"]) == set(agg["cores"])
    print("OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--structures", type=int, default=96)
    ap.add_argument("--batch-size", type=int, default=12)
    ap.add_argument("--n-points", type=int, default=96)
    ap.add_argument("--skip-baseline", action="store_true")
    ap.add_argument("--autoscale", action="store_true",
                    help="elastic cpu/gpu pools (repro.autoscale) instead "
                         "of the static cluster+workstation layout; the "
                         "localize stage then runs on the GPU class")
    ap.add_argument("--sites", type=int, default=1, choices=(1, 2),
                    help="2 = run the campaign on a two-site federation "
                         "(repro.federation): localize pinned to the "
                         "remote HPC site, screen spilling on backlog")
    args = ap.parse_args()

    if args.sites == 2:
        federated_main(args)
        return

    # telemetry plane: stream metrics onto PREFIX-telemetry and hold the
    # campaign to an SLO — queue-wait p95 under 15 s, tested with the
    # SRE-style multi-window burn rate (GET /alerts shows firing rules)
    telemetry_kw = dict(
        telemetry=True,
        slos=[SloSpec(name="queue-wait-p95",
                      metric="ksa_task_queue_wait_seconds:p95",
                      objective=15.0, q=0.95)])
    if args.autoscale:
        # -- elastic pools: the autoscaler grows/shrinks on class backlog --
        from repro.autoscale import AutoscaleConfig, PoolSpec
        cluster = KsaCluster(
            prefix="alphaknot", session_timeout_s=2.0,
            pipeline_task_timeout_s=20.0, http=True,
            autoscale=AutoscaleConfig(
                pools=(PoolSpec("cpu", min_agents=1, max_agents=4, slots=2),
                       PoolSpec("gpu", min_agents=0, max_agents=2, slots=1)),
                interval_s=0.02),
            **telemetry_kw)
    else:
        # -- static pools: one simulated cluster + one workstation ---------
        cluster = KsaCluster(prefix="alphaknot", session_timeout_s=2.0,
                             slurm=dict(nodes=2, cpus_per_node=2,
                                        oversubscribe=2),
                             pipeline_task_timeout_s=20.0, http=True,
                             **telemetry_kw)
    with cluster as c:
        spec = knots.knots_pipeline(args.batch_size, n_points=args.n_points,
                                    task_timeout_s=20.0,
                                    gpu_localize=args.autoscale)
        ids = list(range(args.structures))
        print(f"campaign: {len(ids)} structures through 3-stage pipeline "
              f"{[s.name for s in spec.topological()]}"
              f"{' (autoscaled pools)' if args.autoscale else ''}")

        if not args.autoscale:
            workstation = c.add_worker(slots=1, heartbeat_interval_s=0.2,
                                       profile=None)

            # inject a failure once the campaign is under way (paper-
            # motivating scenario: a node dies mid-campaign; the watchdog
            # redelivers)
            def killer() -> None:
                time.sleep(1.0)
                print("!! killing the workstation agent mid-campaign")
                workstation.crash()
            threading.Thread(target=killer, daemon=True).start()

        last = [0.0]

        def progress(st) -> None:
            if st.progress() - last[0] >= 0.25 or st.done:
                last[0] = st.progress()
                counters = {n: f"{s.done}/{s.expected}"
                            for n, s in st.stages.items()}
                print(f"  progress {st.progress():5.0%}  {counters}")

        res = c.run_campaign(spec, ids, progress=progress, timeout_s=900.0)
        agg = res.final
        print(f"\nprocessed {agg['processed']} structures in "
              f"{res.elapsed_s:.1f}s ({agg['processed']/res.elapsed_s:.1f}/s)"
              f"{'' if args.autoscale else ' despite the failure'}")
        print(f"knotted: {len(agg['knotted'])} "
              f"(expected ~{int(args.structures * 0.75 * 0.85)} — 3 of 4 "
              f"families are knotted, minus pLDDT-style drops)")
        for sid, (a, b) in list(agg["cores"].items())[:5]:
            print(f"  structure {sid}: knot core ≈ residues [{a}, {b})")
        retried = sum(s.retried for s in res.status.stages.values())
        fenced = sum(s.duplicates for s in res.status.stages.values())
        skipped = sum(s.skipped for s in res.status.stages.values())
        print(f"pipeline: {retried} watchdog resubmissions, "
              f"{fenced} duplicate results fenced, "
              f"{skipped} empty localize tasks skipped")
        snap, deadline = None, time.time() + 5.0
        while time.time() < deadline:  # monitor ingests the snapshot async
            try:
                with urllib.request.urlopen(
                        f"http://127.0.0.1:{c.http_port}/campaigns/"
                        f"{res.campaign_id}") as r:
                    snap = json.loads(r.read())
                if snap["state"] != "RUNNING":
                    break
            except urllib.error.HTTPError:
                pass
            time.sleep(0.05)
        stages = ", ".join(f"{n}: {s['done']}/{s['expected']}"
                           for n, s in snap["stages"].items())
        print(f"monitor GET /campaigns/{res.campaign_id}: "
              f"state={snap['state']} stages={{{stages}}}")
        journal = snap.get("journal", {})
        print(f"durability: {journal.get('events', 0)} journal events on "
              f"PREFIX-campaigns (last: {journal.get('last_type', '?')}) — "
              f"an orchestrator kill -9 here would resume via "
              f"KsaCluster.recover()")

        rep = c.campaign_report(res.campaign_id)
        print(f"critical path (campaign_report, also GET /metrics + "
              f"/trace/<task_id>): wall {rep['wall_s']:.1f}s, "
              f"dominant stage '{rep['dominant_stage']}'")
        for name, s in rep["stages"].items():
            print(f"  {name:>9}: queue {s['queue_s']:6.2f}s  "
                  f"run {s['run_s']:6.2f}s  retry {s['retry_s']:5.2f}s  "
                  f"({s['tasks']} tasks, {s['retries']} retried)")

        # telemetry plane (GET /query, /alerts, /blackbox): drain rate from
        # the PREFIX-telemetry time series, the queue-wait SLO's verdict,
        # and a forced flight-recorder post-mortem
        c.telemetry_publisher.publish_once()  # flush the final snapshot
        drain = c.query("ksa_leases_completed_total", agg="rate",
                        window_s=max(10.0, res.elapsed_s))
        p95 = c.query("ksa_task_queue_wait_seconds:p95", agg="latest")
        print(f"telemetry (GET /query): drain rate "
              f"{drain['result']:.1f} tasks/s, queue-wait p95 "
              f"{p95['result'] if p95['result'] is None else round(p95['result'], 3)}s")
        alerts = c.alerts()
        print(f"SLO '{alerts['rules'][0]}' (queue-wait p95 <= 15s): "
              f"{'FIRING ' + str(alerts['firing']) if alerts['firing'] else 'within objective'}")
        dump = c.dump_blackbox("example")   # force a post-mortem snapshot
        print(f"blackbox dump (GET /blackbox): trigger={dump['trigger']}, "
              f"{len(dump['events'])} lifecycle events, "
              f"counts {dump['counts']}")

        if args.autoscale:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{c.http_port}/autoscale") as r:
                scal = json.loads(r.read())
            for cls, p in scal["pools"].items():
                print(f"autoscale {cls}: {p['agents']} agents "
                      f"(min {p['min']}, max {p['max']}), "
                      f"{p['scale_ups']} ups / {p['scale_downs']} downs, "
                      f"backlog now {p['backlog']}")
            for d in scal["decisions"][-6:]:
                print(f"  decision: {d['pool']} {d['action']} x{d['count']} "
                      f"({d['reason']})")

        if not args.skip_baseline:
            base = flat_baseline(c.broker, args.structures, args.batch_size,
                                 args.n_points)
            match = base["knotted"] == agg["knotted"]
            print(f"flat baseline: {len(base['knotted'])} knotted in "
                  f"{base['elapsed_s']:.1f}s — counts "
                  f"{'MATCH' if match else 'MISMATCH'}")
            assert match, (base["knotted"], agg["knotted"])
            assert set(base["cores"]) == set(agg["cores"])
    print("OK")


if __name__ == "__main__":
    main()
