"""Quickstart — the paper's Fig. 3 example, end to end in one process.

A user-defined ``MatrixComputing`` task (extends ``ClusterComputing``)
computes eigenvalues of random matrices. Tasks flow through a
:class:`~repro.cluster.KsaCluster` — the facade that owns the broker, a
simulated Slurm cluster, a workstation worker, and the MonitorAgent with its
REST API (everything the paper wires by hand in §3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import json
import urllib.request

import numpy as np

from repro.cluster import KsaCluster
from repro.core import ClusterComputing, register_script


@register_script("matrix")
class MatrixComputing(ClusterComputing):
    """Paper Fig. 3: the user overrides run(), reads self.params, and may
    emit custom status updates mid-computation."""

    def run(self):
        n = int(self.params.get("n", 128))
        seed = int(self.params.get("seed", 0))
        self.send_status("RUNNING", phase="generating", n=n)
        a = np.random.RandomState(seed).randn(n, n)
        a = (a + a.T) / 2
        self.check_cancel()  # honour the watchdog
        w = np.linalg.eigvalsh(a)
        return {"n": n, "seed": seed,
                "lambda_max": float(w[-1]), "lambda_min": float(w[0])}


def main() -> None:
    # one "cluster" (2 nodes x 2 cpus, simulated Slurm, queue kept full via
    # oversubscription) + one 2-slot workstation worker + monitor REST API
    with KsaCluster(prefix="demo", workers=1, worker_slots=2,
                    slurm=dict(nodes=2, cpus_per_node=2, oversubscribe=4),
                    task_timeout_s=30.0, http=True) as c:
        task_ids = [c.submit("matrix", params={"n": 96, "seed": s},
                             cpus=1, timeout_s=60.0)
                    for s in range(12)]
        print(f"submitted {len(task_ids)} tasks; "
              f"monitor REST on :{c.http_port}")

        assert c.wait_all(task_ids, timeout=120.0), "tasks did not finish"
        for tid in task_ids[:3]:
            print(tid, "->", c.result(tid))

        with urllib.request.urlopen(
                f"http://127.0.0.1:{c.http_port}/summary") as r:
            print("REST /summary:", json.loads(r.read()))
        for a in c.status()["agents"]:
            print(f"{a['kind']} agent {a['agent_id']} completed:",
                  a["completed"])
    print("OK")


if __name__ == "__main__":
    main()
