"""Quickstart — the paper's Fig. 3 example, end to end in one process.

A user-defined ``MatrixComputing`` task (extends ``ClusterComputing``)
computes eigenvalues of random matrices. Tasks flow Submitter → broker →
one ClusterAgent (simulated Slurm cluster) + one WorkerAgent (workstation)
→ MonitorAgent, which also serves the REST API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import json
import time
import urllib.request

import numpy as np

from repro.core import (Broker, ClusterAgent, ClusterComputing, MonitorAgent,
                        SimSlurm, Submitter, WorkerAgent, register_script)


@register_script("matrix")
class MatrixComputing(ClusterComputing):
    """Paper Fig. 3: the user overrides run(), reads self.params, and may
    emit custom status updates mid-computation."""

    def run(self):
        n = int(self.params.get("n", 128))
        seed = int(self.params.get("seed", 0))
        self.send_status("RUNNING", phase="generating", n=n)
        a = np.random.RandomState(seed).randn(n, n)
        a = (a + a.T) / 2
        self.check_cancel()  # honour the watchdog
        w = np.linalg.eigvalsh(a)
        return {"n": n, "seed": seed,
                "lambda_max": float(w[-1]), "lambda_min": float(w[0])}


def main() -> None:
    broker = Broker(default_partitions=4)
    submitter = Submitter(broker, "demo")
    monitor = MonitorAgent(broker, "demo", task_timeout_s=30.0).start()
    port = monitor.start_http(0)

    # one "cluster" (2 nodes x 2 cpus, simulated Slurm) + one workstation
    slurm = SimSlurm(nodes=2, cpus_per_node=2)
    cluster = ClusterAgent(broker, slurm, "demo", oversubscribe=4).start()
    worker = WorkerAgent(broker, "demo", slots=2).start()

    task_ids = [submitter.submit("matrix", params={"n": 96, "seed": s},
                                 cpus=1, timeout_s=60.0)
                for s in range(12)]
    print(f"submitted {len(task_ids)} tasks; monitor REST on :{port}")

    assert monitor.wait_all(task_ids, timeout=120.0), "tasks did not finish"
    for tid in task_ids[:3]:
        print(tid, "->", monitor.task(tid).result)

    with urllib.request.urlopen(f"http://127.0.0.1:{port}/summary") as r:
        print("REST /summary:", json.loads(r.read()))
    print("cluster agent completed:", cluster.tasks_completed,
          "| worker completed:", worker.tasks_completed)

    worker.stop()
    cluster.stop()
    monitor.stop()
    slurm.shutdown()
    broker.close()
    print("OK")


if __name__ == "__main__":
    main()
